//! **Table 3 / E4** — peak GPU memory across the paper's model
//! architectures and GUM configurations, from the analytic accountant
//! (`optim::memory::estimate`) over the real 7–9B shape tables, plus a
//! *measured* small-scale cross-check using live optimizer state sizes.

use crate::model::{init_param_store, paper_shape_table, registry};
use crate::optim::memory::{bytes_human, estimate, Method};
use crate::optim::{self, StepCtx};
use crate::linalg::Matrix;
use crate::rng::Pcg;

use super::ExpOpts;

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    println!("Table 3 — peak memory estimate (GB), paper shapes\n");
    println!(
        "  {:<12} | {:>12} | {:>12} | {:>12}",
        "Model", "GaLore 512", "GUM 4+128", "GUM 2+128"
    );
    println!("  {:-<12}-+-{:-<12}-+-{:-<12}-+-{:-<12}", "", "", "", "");
    for model in paper_shape_table() {
        let ga = estimate(&model, Method::GaLore { rank: 512 });
        let g4 = estimate(&model, Method::Gum { rank: 128, gamma: 4 });
        let g2 = estimate(&model, Method::Gum { rank: 128, gamma: 2 });
        println!(
            "  {:<12} | {:>10.1} G | {:>10.1} G | {:>10.1} G",
            model.name, ga.total_gb, g4.total_gb, g2.total_gb
        );
    }
    println!("\n  breakdown (LLaMA-3-8B, GaLore 512):");
    let m = &paper_shape_table()[0];
    let r = estimate(m, Method::GaLore { rank: 512 });
    println!(
        "    weights {:.1}G  grads {:.1}G  states {:.1}G  acts {:.1}G",
        r.weights_gb, r.grads_gb, r.states_gb, r.activations_gb
    );

    // Measured cross-check at micro scale: live state_bytes of real
    // optimizer instances after one step.
    println!("\n  measured optimizer-state bytes (micro model, live):");
    let cfg = registry::get("micro").unwrap();
    let store = init_param_store(&cfg, opts.seed);
    let mut rng = Pcg::new(opts.seed);
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
        .collect();
    for name in ["adamw", "muon", "galore-muon", "fira", "gum"] {
        let mut opt = optim::build(name, &store, 16, 2.0, opts.seed)?;
        let mut s = store.clone();
        opt.begin_period(&s, &grads, &mut rng);
        opt.step(&mut s, &grads, &StepCtx { lr: 0.01, step: 0 });
        println!("    {:<14} {:>12}", opt.name(), bytes_human(opt.state_bytes()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_micro_ordering_matches_analytic() {
        let cfg = registry::get("micro").unwrap();
        let store = init_param_store(&cfg, 0);
        let mut rng = Pcg::new(0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        let measure = |name: &str, rank: usize, gamma: f64| -> usize {
            let mut opt = optim::build(name, &store, rank, gamma, 0).unwrap();
            let mut s = store.clone();
            opt.begin_period(&s, &grads, &mut rng.clone());
            opt.step(&mut s, &grads, &StepCtx { lr: 0.01, step: 0 });
            opt.state_bytes()
        };
        let galore = measure("galore-muon", 32, 0.0);
        let gum = measure("gum", 8, 2.0);
        let adamw = measure("adamw", 0, 0.0);
        // Projected methods beat full AdamW on state memory.
        assert!(galore < adamw);
        assert!(gum < adamw);
    }
}
