//! Householder QR → orthonormal bases (GoLore's random projectors).

use crate::rng::Pcg;

use super::Matrix;

/// Orthonormalize the columns of `a` (m×k, k ≤ m) via Householder QR;
/// returns the thin Q factor (m×k).
pub fn qr_orthonormal(a: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    qr_orthonormal_into(a, &mut out);
    out
}

/// [`qr_orthonormal`] into a caller-owned output (resized in place) —
/// the buffer-reuse form for the rsvd subspace-iteration loop, which
/// re-orthonormalizes every power step.
pub fn qr_orthonormal_into(a: &Matrix, out: &mut Matrix) {
    let (m, k) = a.shape();
    assert!(k <= m, "qr_orthonormal expects tall input, got {m}x{k}");
    // Work in f64 for stability.
    let mut r: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let idx = |i: usize, j: usize| i * k + j;
    // Householder vectors stored in-place below the diagonal + separate
    // scalar taus.
    let mut taus = vec![0.0f64; k];
    for j in 0..k {
        // Compute the norm of column j below the diagonal.
        let mut norm2 = 0.0;
        for i in j..m {
            norm2 += r[idx(i, j)] * r[idx(i, j)];
        }
        let norm = norm2.sqrt();
        if norm < 1e-300 {
            taus[j] = 0.0;
            continue;
        }
        let alpha = if r[idx(j, j)] >= 0.0 { -norm } else { norm };
        let v0 = r[idx(j, j)] - alpha;
        // v = [v0, r[j+1.., j]]; normalize so v[0] = 1.
        let mut vnorm2 = v0 * v0;
        for i in (j + 1)..m {
            vnorm2 += r[idx(i, j)] * r[idx(i, j)];
        }
        if vnorm2 < 1e-300 {
            taus[j] = 0.0;
            continue;
        }
        let tau = 2.0 * v0 * v0 / vnorm2;
        // Store normalized v below diagonal (v[0]=1 implied).
        for i in (j + 1)..m {
            r[idx(i, j)] /= v0;
        }
        r[idx(j, j)] = alpha;
        taus[j] = tau;
        // Apply H = I − τ v vᵀ to the trailing columns.
        for jj in (j + 1)..k {
            let mut dot = r[idx(j, jj)];
            for i in (j + 1)..m {
                dot += r[idx(i, j)] * r[idx(i, jj)];
            }
            let scale = taus[j] * dot;
            r[idx(j, jj)] -= scale;
            for i in (j + 1)..m {
                let vi = r[idx(i, j)];
                r[idx(i, jj)] -= scale * vi;
            }
        }
    }

    // Form thin Q by applying the Householder reflectors to I (m×k).
    let mut q = vec![0.0f64; m * k];
    for j in 0..k {
        q[idx(j, j)] = 1.0;
    }
    for j in (0..k).rev() {
        if taus[j] == 0.0 {
            continue;
        }
        for jj in 0..k {
            let mut dot = q[idx(j, jj)];
            for i in (j + 1)..m {
                dot += r[idx(i, j)] * q[idx(i, jj)];
            }
            let scale = taus[j] * dot;
            q[idx(j, jj)] -= scale;
            for i in (j + 1)..m {
                let vi = r[idx(i, j)];
                q[idx(i, jj)] -= scale * vi;
            }
        }
    }
    out.resize(m, k);
    for (dst, &src) in out.data.iter_mut().zip(&q) {
        *dst = src as f32;
    }
}

/// Random m×k matrix with orthonormal columns (GoLore projector).
pub fn random_orthonormal(m: usize, k: usize, rng: &mut Pcg) -> Matrix {
    let a = Matrix::randn(m, k, 1.0, rng);
    qr_orthonormal(&a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn};

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg::new(0);
        for (m, k) in [(5, 5), (12, 4), (30, 7), (3, 1)] {
            let q = random_orthonormal(m, k, &mut rng);
            assert_eq!(q.shape(), (m, k));
            let qtq = matmul_tn(&q, &q);
            assert!(
                qtq.max_abs_diff(&Matrix::eye(k)) < 1e-4,
                "({m},{k}) err {}",
                qtq.max_abs_diff(&Matrix::eye(k))
            );
        }
    }

    #[test]
    fn q_spans_input_columns() {
        // Projection of A onto span(Q) must equal A.
        let mut rng = Pcg::new(1);
        let a = Matrix::randn(10, 3, 1.0, &mut rng);
        let q = qr_orthonormal(&a);
        let proj = matmul(&q, &matmul_tn(&q, &a));
        assert!(proj.max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn into_variant_resizes_and_matches() {
        let mut rng = Pcg::new(7);
        let mut q = Matrix::zeros(2, 2);
        for (m, k) in [(12usize, 4usize), (30, 7)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            qr_orthonormal_into(&a, &mut q);
            assert_eq!(q.shape(), (m, k));
            assert_eq!(q.data, qr_orthonormal(&a).data);
        }
    }

    #[test]
    fn handles_degenerate_column() {
        // Second column dependent on the first.
        let a = Matrix::from_vec(4, 2, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        let q = qr_orthonormal(&a);
        assert!(q.is_finite());
        // First column still unit.
        let n0: f32 = (0..4).map(|i| q.at(i, 0) * q.at(i, 0)).sum();
        assert!((n0 - 1.0).abs() < 1e-4);
    }
}
