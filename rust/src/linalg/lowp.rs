//! Reduced-precision optimizer-state storage: bf16 / f16 pack–unpack
//! kernels and fused low-precision variants of the [`super::elementwise`]
//! engine.
//!
//! The paper's memory claim is about optimizer *state*, so this layer
//! pushes the state dtype itself down to 16 bits while keeping every
//! accumulation in f32: each fused kernel unpacks the stored moment
//! bits, runs exactly the f32 arithmetic of its `elementwise` sibling
//! in registers, re-packs the result with round-to-nearest-even, and
//! hands the *unrounded* f32 accumulator to the caller (the
//! Newton–Schulz / project-back input) — no materialized f32 copy of
//! the state ever exists.
//!
//! Kernel set: [`axpby`] (Muon/GUM momentum), [`decay_accumulate2`]
//! (GUM's compensated full-rank momentum), [`adam_update`]
//! (GaLore-Adam / Fira projected moments), [`adam_apply`]
//! (`DenseAdamW`). There is deliberately **no** lowp `residual_add`:
//! Fira's residual pass touches only weights and gradients — it has no
//! moment operand, so the f32 `elementwise::residual_add` is already
//! the whole story at any state dtype.
//!
//! Dispatch and threading follow `elementwise.rs` exactly: one generic
//! scalar body per kernel, compiled per ISA level behind the cached
//! probe in [`super::isa`] (AVX-512F/BW, AVX2+FMA, portable), fanned
//! out over the worker pool above [`PAR_MIN`] elements. Every output
//! element is a pure function of its own index, so results are
//! bit-identical across `GUM_THREADS`, replica splits, and chunk
//! boundaries within a fixed ISA path.
//!
//! Resume semantics: because the packed bits are rounded *after* each
//! update, step t+1 always consumes `unpack(bits_t)` — whether the run
//! is continuous or restored from a checkpoint carrying the same bits
//! — so mid-period resume stays bit-identical at any state dtype.

use super::isa;
use super::Matrix;
use crate::thread::parallel_chunks;

/// Minimum elements per chunk before pool dispatch pays off (same
/// memory-bound reasoning as `elementwise::PAR_MIN`).
const PAR_MIN: usize = 1 << 15;

// ---------------------------------------------------------------------------
// State dtype
// ---------------------------------------------------------------------------

/// Storage dtype for optimizer moment buffers. Projectors and all
/// per-step arithmetic stay f32; this only selects how moments are
/// held between steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateDtype {
    /// Full-precision storage — the default, bit-identical to the
    /// pre-dtype-layer behavior.
    F32,
    /// bfloat16: f32's exponent range, 8-bit mantissa. The robust
    /// default for reduced-precision moments.
    Bf16,
    /// IEEE binary16: 11-bit mantissa but narrow exponent range —
    /// second moments can underflow; offered for experiments.
    F16,
}

impl StateDtype {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> anyhow::Result<StateDtype> {
        match s {
            "f32" | "fp32" => Ok(StateDtype::F32),
            "bf16" | "bfloat16" => Ok(StateDtype::Bf16),
            "f16" | "fp16" | "float16" => Ok(StateDtype::F16),
            _ => anyhow::bail!(
                "unknown state dtype '{s}' (expected f32, bf16, or f16)"
            ),
        }
    }

    /// Canonical label (CLI spelling, metrics, diagnostics).
    pub fn label(self) -> &'static str {
        match self {
            StateDtype::F32 => "f32",
            StateDtype::Bf16 => "bf16",
            StateDtype::F16 => "f16",
        }
    }

    /// Bytes per stored element.
    pub fn bytes(self) -> usize {
        match self {
            StateDtype::F32 => 4,
            StateDtype::Bf16 | StateDtype::F16 => 2,
        }
    }

    /// Stable on-disk tag for the GUMCKPT3 `DTYPE`-tagged moment
    /// sections (absence of a tag ≙ f32, so legacy files never carry
    /// code 0).
    pub fn code(self) -> u8 {
        match self {
            StateDtype::F32 => 0,
            StateDtype::Bf16 => 1,
            StateDtype::F16 => 2,
        }
    }

    /// Inverse of [`StateDtype::code`].
    pub fn from_code(code: u8) -> anyhow::Result<StateDtype> {
        match code {
            0 => Ok(StateDtype::F32),
            1 => Ok(StateDtype::Bf16),
            2 => Ok(StateDtype::F16),
            _ => anyhow::bail!("unknown state-dtype code {code}"),
        }
    }
}

impl std::fmt::Display for StateDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

// ---------------------------------------------------------------------------
// Scalar converters (the reference semantics for every SIMD path)
// ---------------------------------------------------------------------------

/// f32 → bf16 with round-to-nearest-even; NaNs are quieted (payload
/// truncated, quiet bit forced so the result can't collapse to Inf).
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RTNE: add 0x7FFF plus the parity of the kept LSB, then truncate.
    (((bits).wrapping_add(0x7FFF + ((bits >> 16) & 1))) >> 16) as u16
}

/// bf16 → f32 (exact: bf16 is a prefix of f32).
#[inline(always)]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 → IEEE binary16 with round-to-nearest-even, gradual underflow
/// to f16 subnormals, overflow to ±Inf, NaNs quieted.
#[inline(always)]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf or NaN; keep NaN-ness with the quiet bit set.
        return if man != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let e = exp - 127; // unbiased
    if e >= 16 {
        return sign | 0x7C00; // overflow → Inf
    }
    if e < -25 {
        return sign; // below half the smallest subnormal → ±0
    }
    if e < -14 {
        // Subnormal result: implicit bit restored, then RTNE on the
        // (13 + shift) dropped bits. The rounding increment may carry
        // into the exponent field — that is exactly the smallest
        // normal, so the carry is correct as-is.
        let man = man | 0x0080_0000;
        let total = (13 + (-14 - e)) as u32;
        let half = 1u32 << (total - 1);
        let rest = man & ((1u32 << total) - 1);
        let mut h = (man >> total) as u16;
        if rest > half || (rest == half && (h & 1) == 1) {
            h += 1;
        }
        return sign | h;
    }
    // Normal range: 13 dropped mantissa bits, RTNE with carry into the
    // exponent (which may round up to Inf at the top of the range).
    let mut he = (e + 15) as u32;
    let mut hm = man >> 13;
    let rest = man & 0x1FFF;
    if rest > 0x1000 || (rest == 0x1000 && (hm & 1) == 1) {
        hm += 1;
        if hm == 0x400 {
            hm = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7C00;
            }
        }
    }
    sign | ((he as u16) << 10) | (hm as u16)
}

/// IEEE binary16 → f32 (exact).
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: renormalize into the f32 format.
            let mut man = man;
            let mut e = 113u32; // 127 − 14, pre-shift
            while man & 0x0400 == 0 {
                man <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((man & 0x03FF) << 13)
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Const-generic dtype selector for the kernel bodies (hoists the
/// dtype branch out of the inner loops; `StateDtype::F32` never
/// reaches these — the f32 paths stay on `elementwise`).
const DT_BF16: u8 = 0;
const DT_F16: u8 = 1;

#[inline(always)]
fn pack_scalar<const DT: u8>(x: f32) -> u16 {
    if DT == DT_BF16 {
        f32_to_bf16(x)
    } else {
        f32_to_f16(x)
    }
}

#[inline(always)]
fn unpack_scalar<const DT: u8>(b: u16) -> f32 {
    if DT == DT_BF16 {
        bf16_to_f32(b)
    } else {
        f16_to_f32(b)
    }
}

// ---------------------------------------------------------------------------
// Kernel bodies (generic over FMA and dtype, compiled per ISA level)
// ---------------------------------------------------------------------------

#[inline(always)]
fn fma<const FMA: bool>(a: f32, b: f32, c: f32) -> f32 {
    if FMA {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

#[inline(always)]
fn pack_body<const DT: u8>(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = pack_scalar::<DT>(s);
    }
}

#[inline(always)]
fn unpack_body<const DT: u8>(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = unpack_scalar::<DT>(s);
    }
}

/// `acc = a·unpack(bits) + b·y; bits ← pack(acc); out ← acc` — the
/// low-precision sibling of `elementwise::axpby`, with the unrounded
/// accumulator surfaced for the downstream Newton–Schulz input.
#[inline(always)]
fn axpby_body<const FMA: bool, const DT: u8>(
    a: f32,
    bits: &mut [u16],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    debug_assert!(bits.len() == y.len() && bits.len() == out.len());
    for ((bv, &yv), ov) in bits.iter_mut().zip(y).zip(out.iter_mut()) {
        let acc = fma::<FMA>(b, yv, a * unpack_scalar::<DT>(*bv));
        *bv = pack_scalar::<DT>(acc);
        *ov = acc;
    }
}

/// `acc = β·unpack(m) + a·x + b·y; m ← pack(acc); out ← acc` — the
/// low-precision sibling of `elementwise::decay_accumulate2`.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn decay_accumulate2_body<const FMA: bool, const DT: u8>(
    m: &mut [u16],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    debug_assert!(
        m.len() == x.len() && m.len() == y.len() && m.len() == out.len()
    );
    for (((mv, &xv), &yv), ov) in
        m.iter_mut().zip(x).zip(y).zip(out.iter_mut())
    {
        let acc = fma::<FMA>(a, xv, beta * unpack_scalar::<DT>(*mv));
        let acc = fma::<FMA>(b, yv, acc);
        *mv = pack_scalar::<DT>(acc);
        *ov = acc;
    }
}

/// Low-precision sibling of `elementwise::adam_update`: both moment
/// updates run on f32 accumulators unpacked in-register, the packed
/// moments are rewritten RTNE, and the bias-corrected step direction
/// is computed from the unrounded accumulators.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adam_update_body<const FMA: bool, const DT: u8>(
    upd: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    debug_assert!(
        upd.len() == g.len() && upd.len() == m.len() && upd.len() == v.len()
    );
    for (((uv, &gv), mv), vv) in
        upd.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
    {
        let m_new = fma::<FMA>(b1, unpack_scalar::<DT>(*mv), (1.0 - b1) * gv);
        let v_new =
            fma::<FMA>(b2, unpack_scalar::<DT>(*vv), (1.0 - b2) * gv * gv);
        *mv = pack_scalar::<DT>(m_new);
        *vv = pack_scalar::<DT>(v_new);
        *uv = (m_new / bc1) / ((v_new / bc2).sqrt() + eps);
    }
}

/// Low-precision sibling of `elementwise::adam_apply` (`DenseAdamW`'s
/// whole step with 16-bit moment storage).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adam_apply_body<const FMA: bool, const DT: u8>(
    w: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    debug_assert!(
        w.len() == g.len() && w.len() == m.len() && w.len() == v.len()
    );
    for (((wv, &gv), mv), vv) in
        w.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
    {
        let m_new = fma::<FMA>(b1, unpack_scalar::<DT>(*mv), (1.0 - b1) * gv);
        let v_new =
            fma::<FMA>(b2, unpack_scalar::<DT>(*vv), (1.0 - b2) * gv * gv);
        *mv = pack_scalar::<DT>(m_new);
        *vv = pack_scalar::<DT>(v_new);
        let mhat = m_new / bc1;
        let vhat = v_new / bc2;
        let mut x = *wv;
        if wd > 0.0 {
            x -= lr * wd * x;
        }
        *wv = x - lr * mhat / (vhat.sqrt() + eps);
    }
}

// ---------------------------------------------------------------------------
// ISA specializations (same bodies, compiled under target_feature so
// the converters and fused loops autovectorize per path)
// ---------------------------------------------------------------------------

/// SAFETY (all fns): callers must have verified avx2 + fma support —
/// the [`isa::level`] match gates every call site.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn pack<const DT: u8>(src: &[f32], dst: &mut [u16]) {
        pack_body::<DT>(src, dst)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn unpack<const DT: u8>(src: &[u16], dst: &mut [f32]) {
        unpack_body::<DT>(src, dst)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpby<const DT: u8>(
        a: f32,
        bits: &mut [u16],
        b: f32,
        y: &[f32],
        out: &mut [f32],
    ) {
        axpby_body::<true, DT>(a, bits, b, y, out)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn decay_accumulate2<const DT: u8>(
        m: &mut [u16],
        beta: f32,
        a: f32,
        x: &[f32],
        b: f32,
        y: &[f32],
        out: &mut [f32],
    ) {
        decay_accumulate2_body::<true, DT>(m, beta, a, x, b, y, out)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn adam_update<const DT: u8>(
        upd: &mut [f32],
        g: &[f32],
        m: &mut [u16],
        v: &mut [u16],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    ) {
        adam_update_body::<true, DT>(upd, g, m, v, b1, b2, bc1, bc2, eps)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn adam_apply<const DT: u8>(
        w: &mut [f32],
        g: &[f32],
        m: &mut [u16],
        v: &mut [u16],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
        lr: f32,
        wd: f32,
    ) {
        adam_apply_body::<true, DT>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
    }
}

/// SAFETY (all fns): callers must have verified avx512f + avx512bw
/// support — the [`isa::level`] match gates every call site. BW
/// matters here: the 16-bit packs/shuffles the converters compile to
/// need 512-bit word-granularity ops.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use super::*;

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn pack<const DT: u8>(src: &[f32], dst: &mut [u16]) {
        pack_body::<DT>(src, dst)
    }

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn unpack<const DT: u8>(src: &[u16], dst: &mut [f32]) {
        unpack_body::<DT>(src, dst)
    }

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn axpby<const DT: u8>(
        a: f32,
        bits: &mut [u16],
        b: f32,
        y: &[f32],
        out: &mut [f32],
    ) {
        axpby_body::<true, DT>(a, bits, b, y, out)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn decay_accumulate2<const DT: u8>(
        m: &mut [u16],
        beta: f32,
        a: f32,
        x: &[f32],
        b: f32,
        y: &[f32],
        out: &mut [f32],
    ) {
        decay_accumulate2_body::<true, DT>(m, beta, a, x, b, y, out)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn adam_update<const DT: u8>(
        upd: &mut [f32],
        g: &[f32],
        m: &mut [u16],
        v: &mut [u16],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    ) {
        adam_update_body::<true, DT>(upd, g, m, v, b1, b2, bc1, bc2, eps)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn adam_apply<const DT: u8>(
        w: &mut [f32],
        g: &[f32],
        m: &mut [u16],
        v: &mut [u16],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
        lr: f32,
        wd: f32,
    ) {
        adam_apply_body::<true, DT>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
    }
}

// ---------------------------------------------------------------------------
// Serial dispatchers
// ---------------------------------------------------------------------------

fn pack_serial<const DT: u8>(src: &[f32], dst: &mut [u16]) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => return unsafe { avx512::pack::<DT>(src, dst) },
        isa::IsaLevel::Avx2 => return unsafe { avx2::pack::<DT>(src, dst) },
        isa::IsaLevel::Portable => {}
    }
    pack_body::<DT>(src, dst)
}

fn unpack_serial<const DT: u8>(src: &[u16], dst: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe { avx512::unpack::<DT>(src, dst) }
        }
        isa::IsaLevel::Avx2 => return unsafe { avx2::unpack::<DT>(src, dst) },
        isa::IsaLevel::Portable => {}
    }
    unpack_body::<DT>(src, dst)
}

fn axpby_serial<const DT: u8>(
    a: f32,
    bits: &mut [u16],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe { avx512::axpby::<DT>(a, bits, b, y, out) }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe { avx2::axpby::<DT>(a, bits, b, y, out) }
        }
        isa::IsaLevel::Portable => {}
    }
    axpby_body::<false, DT>(a, bits, b, y, out)
}

#[allow(clippy::too_many_arguments)]
fn decay_accumulate2_serial<const DT: u8>(
    m: &mut [u16],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe {
                avx512::decay_accumulate2::<DT>(m, beta, a, x, b, y, out)
            }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe {
                avx2::decay_accumulate2::<DT>(m, beta, a, x, b, y, out)
            }
        }
        isa::IsaLevel::Portable => {}
    }
    decay_accumulate2_body::<false, DT>(m, beta, a, x, b, y, out)
}

#[allow(clippy::too_many_arguments)]
fn adam_update_serial<const DT: u8>(
    upd: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe {
                avx512::adam_update::<DT>(upd, g, m, v, b1, b2, bc1, bc2, eps)
            }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe {
                avx2::adam_update::<DT>(upd, g, m, v, b1, b2, bc1, bc2, eps)
            }
        }
        isa::IsaLevel::Portable => {}
    }
    adam_update_body::<false, DT>(upd, g, m, v, b1, b2, bc1, bc2, eps)
}

#[allow(clippy::too_many_arguments)]
fn adam_apply_serial<const DT: u8>(
    w: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe {
                avx512::adam_apply::<DT>(
                    w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd,
                )
            }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe {
                avx2::adam_apply::<DT>(
                    w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd,
                )
            }
        }
        isa::IsaLevel::Portable => {}
    }
    adam_apply_body::<false, DT>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
}

// ---------------------------------------------------------------------------
// Parallel fan-out plumbing (u16 + f32 siblings of elementwise's)
// ---------------------------------------------------------------------------

struct SendMutF32(*mut f32);
unsafe impl Sync for SendMutF32 {}
unsafe impl Send for SendMutF32 {}

struct SendConstF32(*const f32);
unsafe impl Sync for SendConstF32 {}
unsafe impl Send for SendConstF32 {}

struct SendMutU16(*mut u16);
unsafe impl Sync for SendMutU16 {}
unsafe impl Send for SendMutU16 {}

/// Re-slice a mutable base pointer to one chunk's exclusive range.
///
/// SAFETY: callers pass disjoint `[start, end)` ranges per chunk (the
/// `parallel_chunks` contract) and the owning slice outlives the
/// blocking dispatch.
unsafe fn chunk_mut_f32<'a>(
    p: *mut f32,
    start: usize,
    end: usize,
) -> &'a mut [f32] {
    unsafe { std::slice::from_raw_parts_mut(p.add(start), end - start) }
}

/// Immutable sibling of [`chunk_mut_f32`]. SAFETY: as above.
unsafe fn chunk_ref_f32<'a>(
    p: *const f32,
    start: usize,
    end: usize,
) -> &'a [f32] {
    unsafe { std::slice::from_raw_parts(p.add(start), end - start) }
}

/// u16 sibling of [`chunk_mut_f32`]. SAFETY: as above.
unsafe fn chunk_mut_u16<'a>(
    p: *mut u16,
    start: usize,
    end: usize,
) -> &'a mut [u16] {
    unsafe { std::slice::from_raw_parts_mut(p.add(start), end - start) }
}

// ---------------------------------------------------------------------------
// Public entry points (dtype dispatch + pool threading)
// ---------------------------------------------------------------------------

/// Expect a 16-bit dtype; the f32 paths never reach this module.
#[track_caller]
fn expect_lowp(dtype: StateDtype) {
    assert!(
        dtype != StateDtype::F32,
        "lowp kernels take a 16-bit state dtype; f32 stays on elementwise"
    );
}

/// Pack f32 values into 16-bit storage (RTNE), pool-threaded.
pub fn pack_slice(dtype: StateDtype, src: &[f32], dst: &mut [u16]) {
    expect_lowp(dtype);
    assert_eq!(src.len(), dst.len(), "pack_slice length mismatch");
    let sp = SendConstF32(src.as_ptr());
    let dp = SendMutU16(dst.as_mut_ptr());
    parallel_chunks(dst.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ss, ds) =
            unsafe { (chunk_ref_f32(sp.0, s, e), chunk_mut_u16(dp.0, s, e)) };
        match dtype {
            StateDtype::Bf16 => pack_serial::<DT_BF16>(ss, ds),
            _ => pack_serial::<DT_F16>(ss, ds),
        }
    });
}

/// Unpack 16-bit storage into f32 (exact), pool-threaded.
pub fn unpack_slice(dtype: StateDtype, src: &[u16], dst: &mut [f32]) {
    expect_lowp(dtype);
    assert_eq!(src.len(), dst.len(), "unpack_slice length mismatch");
    let sp = SendMutU16(src.as_ptr() as *mut u16);
    let dp = SendMutF32(dst.as_mut_ptr());
    parallel_chunks(dst.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks (src is only read); operands outlive
        // the dispatch.
        let (ss, ds) = unsafe {
            (
                std::slice::from_raw_parts(sp.0.add(s).cast_const(), e - s),
                chunk_mut_f32(dp.0, s, e),
            )
        };
        match dtype {
            StateDtype::Bf16 => unpack_serial::<DT_BF16>(ss, ds),
            _ => unpack_serial::<DT_F16>(ss, ds),
        }
    });
}

/// Fused momentum update on packed state:
/// `acc = a·unpack(bits) + b·y`, `bits ← pack(acc)`, `out ← acc`.
/// `out` carries the unrounded f32 accumulator (the Newton–Schulz /
/// project-back input), so no f32 copy of the *stored* state exists.
pub fn axpby(
    dtype: StateDtype,
    a: f32,
    bits: &mut [u16],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    expect_lowp(dtype);
    assert!(
        bits.len() == y.len() && bits.len() == out.len(),
        "lowp axpby length mismatch"
    );
    let bp = SendMutU16(bits.as_mut_ptr());
    let yp = SendConstF32(y.as_ptr());
    let op = SendMutF32(out.as_mut_ptr());
    parallel_chunks(bits.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (bs, ys, os) = unsafe {
            (
                chunk_mut_u16(bp.0, s, e),
                chunk_ref_f32(yp.0, s, e),
                chunk_mut_f32(op.0, s, e),
            )
        };
        match dtype {
            StateDtype::Bf16 => axpby_serial::<DT_BF16>(a, bs, b, ys, os),
            _ => axpby_serial::<DT_F16>(a, bs, b, ys, os),
        }
    });
}

/// Fused decay + two scaled accumulates on packed state:
/// `acc = β·unpack(m) + a·x + b·y`, `m ← pack(acc)`, `out ← acc` —
/// GUM's compensated full-rank momentum at 16-bit storage.
#[allow(clippy::too_many_arguments)]
pub fn decay_accumulate2(
    dtype: StateDtype,
    m: &mut [u16],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
    out: &mut [f32],
) {
    expect_lowp(dtype);
    assert!(
        m.len() == x.len() && m.len() == y.len() && m.len() == out.len(),
        "lowp decay_accumulate2 length mismatch"
    );
    let mp = SendMutU16(m.as_mut_ptr());
    let xp = SendConstF32(x.as_ptr());
    let yp = SendConstF32(y.as_ptr());
    let op = SendMutF32(out.as_mut_ptr());
    parallel_chunks(m.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ms, xs, ys, os) = unsafe {
            (
                chunk_mut_u16(mp.0, s, e),
                chunk_ref_f32(xp.0, s, e),
                chunk_ref_f32(yp.0, s, e),
                chunk_mut_f32(op.0, s, e),
            )
        };
        match dtype {
            StateDtype::Bf16 => {
                decay_accumulate2_serial::<DT_BF16>(ms, beta, a, xs, b, ys, os)
            }
            _ => decay_accumulate2_serial::<DT_F16>(ms, beta, a, xs, b, ys, os),
        }
    });
}

/// Fused Adam moment update + bias-corrected step direction on packed
/// moments (GaLore-Adam / Fira projected state at 16-bit storage).
#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    dtype: StateDtype,
    upd: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    expect_lowp(dtype);
    assert!(
        upd.len() == g.len() && upd.len() == m.len() && upd.len() == v.len(),
        "lowp adam_update length mismatch"
    );
    let up = SendMutF32(upd.as_mut_ptr());
    let gp = SendConstF32(g.as_ptr());
    let mp = SendMutU16(m.as_mut_ptr());
    let vp = SendMutU16(v.as_mut_ptr());
    parallel_chunks(upd.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (us, gs, ms, vs) = unsafe {
            (
                chunk_mut_f32(up.0, s, e),
                chunk_ref_f32(gp.0, s, e),
                chunk_mut_u16(mp.0, s, e),
                chunk_mut_u16(vp.0, s, e),
            )
        };
        match dtype {
            StateDtype::Bf16 => adam_update_serial::<DT_BF16>(
                us, gs, ms, vs, b1, b2, bc1, bc2, eps,
            ),
            _ => adam_update_serial::<DT_F16>(
                us, gs, ms, vs, b1, b2, bc1, bc2, eps,
            ),
        }
    });
}

/// Fused AdamW step with packed moments (`DenseAdamW` at 16-bit
/// storage): weights stay f32, moments are unpacked/re-packed
/// in-register.
#[allow(clippy::too_many_arguments)]
pub fn adam_apply(
    dtype: StateDtype,
    w: &mut [f32],
    g: &[f32],
    m: &mut [u16],
    v: &mut [u16],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    expect_lowp(dtype);
    assert!(
        w.len() == g.len() && w.len() == m.len() && w.len() == v.len(),
        "lowp adam_apply length mismatch"
    );
    let wp = SendMutF32(w.as_mut_ptr());
    let gp = SendConstF32(g.as_ptr());
    let mp = SendMutU16(m.as_mut_ptr());
    let vp = SendMutU16(v.as_mut_ptr());
    parallel_chunks(w.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ws, gs, ms, vs) = unsafe {
            (
                chunk_mut_f32(wp.0, s, e),
                chunk_ref_f32(gp.0, s, e),
                chunk_mut_u16(mp.0, s, e),
                chunk_mut_u16(vp.0, s, e),
            )
        };
        match dtype {
            StateDtype::Bf16 => adam_apply_serial::<DT_BF16>(
                ws, gs, ms, vs, b1, b2, bc1, bc2, eps, lr, wd,
            ),
            _ => adam_apply_serial::<DT_F16>(
                ws, gs, ms, vs, b1, b2, bc1, bc2, eps, lr, wd,
            ),
        }
    });
}

// ---------------------------------------------------------------------------
// MomentBuf: a moment matrix stored at the configured state dtype
// ---------------------------------------------------------------------------

/// One optimizer moment buffer at the configured state dtype. The f32
/// variant wraps the plain [`Matrix`] the optimizers always used (so
/// the default path is call-for-call identical to the pre-dtype
/// layer); the 16-bit variant stores packed bits plus the row-major
/// shape.
#[derive(Debug, Clone, PartialEq)]
pub enum MomentBuf {
    /// Full-precision moments (the default path).
    F32(Matrix),
    /// 16-bit packed moments, row-major `rows × cols`.
    Lowp {
        dtype: StateDtype,
        rows: usize,
        cols: usize,
        bits: Vec<u16>,
    },
}

impl MomentBuf {
    /// All-zero moments at the given dtype (0.0 packs to 0 bits in
    /// both 16-bit formats, so a zeroed bits vector is exact).
    pub fn zeros(dtype: StateDtype, rows: usize, cols: usize) -> MomentBuf {
        match dtype {
            StateDtype::F32 => MomentBuf::F32(Matrix::zeros(rows, cols)),
            _ => MomentBuf::Lowp {
                dtype,
                rows,
                cols,
                bits: vec![0u16; rows * cols],
            },
        }
    }

    pub fn dtype(&self) -> StateDtype {
        match self {
            MomentBuf::F32(_) => StateDtype::F32,
            MomentBuf::Lowp { dtype, .. } => *dtype,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            MomentBuf::F32(m) => m.shape(),
            MomentBuf::Lowp { rows, cols, .. } => (*rows, *cols),
        }
    }

    pub fn numel(&self) -> usize {
        let (r, c) = self.shape();
        r * c
    }

    /// Bytes of stored state — the quantity `opt_state_bytes` sums.
    pub fn state_bytes(&self) -> usize {
        self.numel() * self.dtype().bytes()
    }

    /// Unpack (or copy) into an f32 matrix, resizing `out` in place.
    pub fn unpack_into(&self, out: &mut Matrix) {
        let (r, c) = self.shape();
        out.resize(r, c);
        match self {
            MomentBuf::F32(m) => out.data.copy_from_slice(&m.data),
            MomentBuf::Lowp { dtype, bits, .. } => {
                unpack_slice(*dtype, bits, &mut out.data)
            }
        }
    }

    /// The f32 matrix, when stored at full precision.
    pub fn as_f32(&self) -> Option<&Matrix> {
        match self {
            MomentBuf::F32(m) => Some(m),
            MomentBuf::Lowp { .. } => None,
        }
    }

    /// Mutable sibling of [`MomentBuf::as_f32`].
    pub fn as_f32_mut(&mut self) -> Option<&mut Matrix> {
        match self {
            MomentBuf::F32(m) => Some(m),
            MomentBuf::Lowp { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trips_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.5, 0.15625, 3.0e38, -2.0e-38] {
            let b = f32_to_bf16(x);
            let back = bf16_to_f32(b);
            // These all have ≤8 significant mantissa bits.
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2^-9 sits exactly between 1.0 and 1 + 2^-8: ties to even
        // (the even neighbor is 1.0).
        let tie = f32::from_bits(0x3F80_0080);
        assert_eq!(bf16_to_f32(f32_to_bf16(tie)), 1.0);
        // Just above the tie rounds up.
        let above = f32::from_bits(0x3F80_0081);
        assert_eq!(
            bf16_to_f32(f32_to_bf16(above)).to_bits(),
            0x3F81_0000u32
        );
        // The odd-neighbor tie rounds *up* to the even value.
        let tie_odd = f32::from_bits(0x3F81_8000); // 1.01171875 + tie
        assert_eq!(
            bf16_to_f32(f32_to_bf16(tie_odd)).to_bits(),
            0x3F82_0000u32
        );
    }

    #[test]
    fn f16_round_trips_and_edges() {
        for x in [0.0f32, -0.0, 1.0, -2.5, 0.5, 65504.0, 6.1035156e-5] {
            let h = f32_to_f16(x);
            assert_eq!(f16_to_f32(h), x, "{x}");
        }
        // Overflow → Inf; subnormal survives; tiny → 0.
        assert_eq!(f16_to_f32(f32_to_f16(1.0e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1.0e6)), f32::NEG_INFINITY);
        let sub = 5.9604645e-8; // smallest f16 subnormal
        assert_eq!(f16_to_f32(f32_to_f16(sub)), sub);
        assert_eq!(f16_to_f32(f32_to_f16(1.0e-12)), 0.0);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn pack_unpack_slices_round_trip() {
        let src: Vec<f32> =
            (0..1000).map(|i| ((i % 37) as f32 - 18.0) * 0.25).collect();
        for dtype in [StateDtype::Bf16, StateDtype::F16] {
            let mut bits = vec![0u16; src.len()];
            pack_slice(dtype, &src, &mut bits);
            let mut back = vec![0.0f32; src.len()];
            unpack_slice(dtype, &bits, &mut back);
            for (i, (&b, &s)) in back.iter().zip(&src).enumerate() {
                // Quarter-steps up to 4.5 are exact in both formats.
                assert_eq!(b, s, "{dtype} idx {i}");
            }
        }
    }

    #[test]
    fn lowp_axpby_matches_scalar_composition() {
        let n = 257;
        let y: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.3).collect();
        let m0: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 0.5).collect();
        let mut bits: Vec<u16> = m0.iter().map(|&x| f32_to_bf16(x)).collect();
        let mut out = vec![0.0f32; n];
        axpby(StateDtype::Bf16, 0.9, &mut bits, 1.0, &y, &mut out);
        for i in 0..n {
            let want = 0.9f32 * bf16_to_f32(f32_to_bf16(m0[i])) + y[i];
            assert!(
                (out[i] - want).abs() <= 1e-6 * want.abs().max(1.0),
                "idx {i}"
            );
            assert_eq!(bits[i], f32_to_bf16(out[i]), "repack idx {i}");
        }
    }

    #[test]
    fn moment_buf_zeros_and_bytes() {
        let f = MomentBuf::zeros(StateDtype::F32, 3, 5);
        let b = MomentBuf::zeros(StateDtype::Bf16, 3, 5);
        assert_eq!(f.state_bytes(), 60);
        assert_eq!(b.state_bytes(), 30);
        assert_eq!(b.shape(), (3, 5));
        let mut out = Matrix::zeros(1, 1);
        b.unpack_into(&mut out);
        assert_eq!(out.shape(), (3, 5));
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dtype_parse_and_codes() {
        assert_eq!(StateDtype::parse("bf16").unwrap(), StateDtype::Bf16);
        assert_eq!(StateDtype::parse("f32").unwrap(), StateDtype::F32);
        assert_eq!(StateDtype::parse("f16").unwrap(), StateDtype::F16);
        assert!(StateDtype::parse("int8").is_err());
        for d in [StateDtype::F32, StateDtype::Bf16, StateDtype::F16] {
            assert_eq!(StateDtype::from_code(d.code()).unwrap(), d);
        }
        assert!(StateDtype::from_code(9).is_err());
    }
}
