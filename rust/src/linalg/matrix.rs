//! Row-major dense f32 matrix.

use crate::rng::Pcg;

/// Dense row-major matrix of `f32`.
///
/// Row-major matches both the PJRT literal layout and the canonical
/// NumPy layout of the AOT artifacts, so buffers cross the runtime
/// boundary without transposition.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Default for Matrix {
    /// An empty 0×0 matrix — the idiomatic initial state for scratch
    /// buffers that are `resize`d on first use.
    fn default() -> Matrix {
        Matrix::zeros(0, 0)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f32,
    ) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(std * rng.normal_f32());
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Reshape to rows×cols in place, reusing the allocation (the
    /// scratch-buffer idiom behind `matmul_*_into` and the optimizer
    /// step scratch). Existing contents are unspecified afterwards —
    /// callers are expected to overwrite every element.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a caller-owned buffer (resized in place).
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.resize(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] =
                            self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Copy `other`'s contents into self, resizing as needed.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.resize(other.rows, other.cols);
        self.data.copy_from_slice(&other.data);
    }

    /// First `k` columns as a new matrix (used for U[:, :r]).
    pub fn left_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i)
                .copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    // ----- elementwise ops (allocation-free variants used in hot loops,
    // dispatched to the fused SIMD engine in `linalg::elementwise`) -----

    pub fn scale_in_place(&mut self, a: f32) {
        super::elementwise::scale(&mut self.data, a);
    }

    /// self = a*self + b*other
    pub fn axpby_in_place(&mut self, a: f32, b: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        super::elementwise::axpby(a, &mut self.data, b, &other.data);
    }

    /// self += a * other
    pub fn add_scaled_in_place(&mut self, a: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        super::elementwise::add_scaled(&mut self.data, a, &other.data);
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn scaled(&self, a: f32) -> Matrix {
        let mut out = self.clone();
        out.scale_in_place(a);
        out
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.numel(), 6);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg::new(0);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.at(5, 7), m.at(7, 5));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn left_cols_slices() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let l = m.left_cols(2);
        assert_eq!(l.shape(), (3, 2));
        assert_eq!(l.at(2, 1), 9.0);
    }

    #[test]
    fn axpby() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![10.0, 20.0, 30.0]);
        a.axpby_in_place(2.0, 0.5, &b);
        assert_eq!(a.data, vec![7.0, 14.0, 21.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.sub(&b);
    }

    #[test]
    fn resize_reuses_allocation() {
        let mut m = Matrix::zeros(8, 8);
        let cap = m.data.capacity();
        m.resize(4, 6);
        assert_eq!(m.shape(), (4, 6));
        assert_eq!(m.data.len(), 24);
        assert!(m.data.capacity() >= cap.min(64));
        m.resize(10, 2);
        assert_eq!(m.data.len(), 20);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Pcg::new(5);
        let m = Matrix::randn(13, 29, 1.0, &mut rng);
        let mut out = Matrix::zeros(1, 1);
        m.transpose_into(&mut out);
        assert_eq!(out, m.transpose());
    }

    #[test]
    fn eye_and_identity_property() {
        let i = Matrix::eye(4);
        assert_eq!(i.at(2, 2), 1.0);
        assert_eq!(i.at(2, 3), 0.0);
    }
}
