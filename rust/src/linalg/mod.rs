//! Dense linear-algebra substrate (f32 row-major), built in-tree.
//!
//! Everything the optimizers need: blocked+threaded GEMM, symmetric
//! Jacobi eigendecomposition → thin SVD (GaLore projector), randomized
//! warm-startable low-rank SVD (the fast projector-refresh engine),
//! Householder QR (random orthonormal projectors for GoLore),
//! Newton–Schulz `msign` (Muon), norms and spectra (stable rank,
//! Figs. 2/3/5).

mod gemm;
mod matrix;
mod newton_schulz;
mod norms;
mod qr;
mod rsvd;
mod svd;

pub use gemm::{gemm, matmul, matmul_nt, matmul_tn};
pub use matrix::Matrix;
pub use newton_schulz::{msign_exact, newton_schulz, NS_COEFFS, NS_STEPS};
pub use norms::{fro_norm, spectral_norm_est, stable_rank, trace_norm};
pub use qr::{qr_orthonormal, random_orthonormal};
pub use rsvd::{
    randomized_range, rsvd, top_singular_vectors_randomized, RsvdOpts,
};
pub use svd::{singular_values, svd_thin, top_singular_vectors, Svd};
