//! Dense linear-algebra substrate (f32 row-major), built in-tree.
//!
//! Everything the optimizers need: packed cache-blocked threaded GEMM
//! (one register microkernel behind the NN/NT/TN paths plus `_into`
//! variants for buffer reuse, with a size-threshold cutover to an
//! unpacked kernel for tiny blocks), fused single-pass SIMD elementwise
//! kernels for the optimizer state updates ([`elementwise`]), symmetric
//! Jacobi eigendecomposition → thin SVD (GaLore projector), randomized
//! warm-startable low-rank SVD (the fast projector-refresh engine),
//! Householder QR (random orthonormal projectors for GoLore),
//! Newton–Schulz `msign` (Muon, workspace-reusing `_into` form for the
//! per-step hot loop), norms and spectra (stable rank, Figs. 2/3/5).
//!
//! GEMM tiling is resolved per call by [`tune`]: off by default (the
//! fixed blocking), opt-in measured per-shape-class tile search with a
//! persisted per-host cache (`GUM_TUNE`, `GUM_TUNE_CACHE`,
//! `--tune-cache`). Tile choice never breaks the crate's determinism
//! contract: for a given choice, results are bit-identical across
//! `GUM_THREADS`.

pub mod elementwise;
mod gemm;
pub mod isa;
pub mod lowp;
mod matrix;
mod newton_schulz;
mod norms;
mod qr;
mod rsvd;
mod svd;
pub mod tune;

pub use gemm::{
    dot, gemm, gemm_forced, gemm_nt, gemm_tn, matmul, matmul_into, matmul_nt,
    matmul_nt_into, matmul_tn, matmul_tn_into,
};
pub use matrix::Matrix;
pub use newton_schulz::{
    msign_exact, newton_schulz, newton_schulz_into, NsWorkspace, NS_COEFFS,
    NS_STEPS,
};
pub use norms::{fro_norm, spectral_norm_est, stable_rank, trace_norm};
pub use qr::{qr_orthonormal, qr_orthonormal_into, random_orthonormal};
pub use rsvd::{
    randomized_range, rsvd, top_singular_vectors_randomized, RsvdOpts,
};
pub use svd::{singular_values, svd_thin, top_singular_vectors, Svd};
