//! Randomized low-rank SVD (Halko–Martinsson–Tropp) with warm-started
//! subspace iteration — the projector-refresh engine.
//!
//! GaLore/GUM only need the top-r left singular vectors of a gradient
//! block, so paying for a full Gram eigendecomposition every refresh is
//! waste: a Gaussian sketch captures the dominant subspace in O(mnl)
//! GEMM flops (l = r + oversample), and q steps of power iteration with
//! QR re-orthonormalization sharpen it to working accuracy for the
//! separated spectra these optimizers exploit. Warm starts go further:
//! seeding the range-finder with the *previous period's* projector means
//! steady-state refreshes converge in 1–2 iterations, because the
//! subspace drifts slowly between periods.
//!
//! Numerics: the GEMM sketches run in f32 (threaded, deterministic), but
//! every orthogonality-critical reduction is f64 — Householder QR
//! (`qr_orthonormal`) and the small projected eigenproblem (`svd_thin`'s
//! Gram + cyclic Jacobi) both accumulate in f64. All randomness flows
//! from the caller's seeded [`Pcg`] stream; callers derive dedicated
//! child streams via [`crate::rng::derive_seed`] so sketch draws never
//! perturb unrelated sampling (e.g. GUM's Bernoulli mask).

use crate::rng::Pcg;

use super::{
    matmul, matmul_into, matmul_tn, matmul_tn_into, qr_orthonormal,
    qr_orthonormal_into, Matrix, Svd,
};

/// Tuning knobs for the randomized range-finder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RsvdOpts {
    /// Extra sketch columns beyond the target rank (l = r + oversample).
    pub oversample: usize,
    /// Power/subspace iterations after the initial sketch. Warm starts
    /// always run at least one so the basis tracks the *current* matrix.
    pub power_iters: usize,
}

impl Default for RsvdOpts {
    fn default() -> Self {
        RsvdOpts {
            oversample: 4,
            power_iters: 2,
        }
    }
}

/// Orthonormal basis Q (m×l) approximating the range of `a` (m×n).
///
/// Cold start: Q₀ = orth(A·Ω) with Gaussian Ω (n×l). Warm start: Q₀ =
/// orth([P_prev | Gaussian pad]) — the previous projector seeds the
/// sketch directly in the output space, and the mandatory subspace
/// iteration (Q ← orth(A·(Aᵀ·Q))) pulls it onto the current range. A
/// warm basis whose row count does not match `a` is ignored.
pub fn randomized_range(
    a: &Matrix,
    r: usize,
    opts: &RsvdOpts,
    warm: Option<&Matrix>,
    rng: &mut Pcg,
) -> Matrix {
    let (m, n) = a.shape();
    let side = m.min(n);
    let r = r.min(side);
    let l = (r + opts.oversample).min(side);
    let warm = warm.filter(|w| w.rows == m && w.cols > 0);

    let mut q = match warm {
        Some(w) => {
            // Previous basis + fresh Gaussian columns up to the sketch
            // width, re-orthonormalized.
            let keep = w.cols.min(l);
            let mut y = Matrix::zeros(m, l);
            for i in 0..m {
                let row = y.row_mut(i);
                row[..keep].copy_from_slice(&w.row(i)[..keep]);
                for v in row[keep..].iter_mut() {
                    *v = rng.normal_f32();
                }
            }
            qr_orthonormal(&y)
        }
        None => {
            let omega = Matrix::randn(n, l, 1.0, rng);
            qr_orthonormal(&matmul(a, &omega))
        }
    };

    let iters = if warm.is_some() {
        opts.power_iters.max(1)
    } else {
        opts.power_iters
    };
    // The subspace iteration reuses two product buffers across power
    // steps — with the packed TN kernel nothing in this loop transposes
    // or allocates once the buffers are warm.
    let mut atq = Matrix::zeros(0, 0);
    let mut aq = Matrix::zeros(0, 0);
    for _ in 0..iters {
        // Q ← orth(A Aᵀ Q) without forming A Aᵀ.
        matmul_tn_into(a, &q, &mut atq); // n×l
        matmul_into(a, &atq, &mut aq); // m×l
        qr_orthonormal_into(&aq, &mut q);
    }
    q
}

/// Truncated randomized SVD: `a ≈ u · diag(s) · vt` with `u` m×r,
/// `vt` r×n, singular values descending. The range basis is rotated onto
/// the singular basis by an *exact* (f64 Jacobi) SVD of the small
/// projected matrix B = QᵀA, so the only approximation is the range
/// capture itself.
pub fn rsvd(
    a: &Matrix,
    r: usize,
    opts: &RsvdOpts,
    warm: Option<&Matrix>,
    rng: &mut Pcg,
) -> Svd {
    let q = randomized_range(a, r, opts, warm, rng);
    let b = matmul_tn(&q, a); // l×n, small
    let svd_b = super::svd_thin(&b);
    let rr = r
        .min(a.rows.min(a.cols))
        .min(q.cols)
        .min(svd_b.s.len());
    let u = matmul(&q, &svd_b.u.left_cols(rr));
    let s = svd_b.s[..rr].to_vec();
    let vt = Matrix::from_vec(rr, b.cols, svd_b.vt.data[..rr * b.cols].to_vec());
    Svd { u, s, vt }
}

/// Top-r left singular vectors via randomized subspace iteration —
/// compatibility wrapper over [`rsvd`] with the default oversampling.
pub fn top_singular_vectors_randomized(
    a: &Matrix,
    r: usize,
    iters: usize,
    rng: &mut Pcg,
) -> Matrix {
    let opts = RsvdOpts {
        oversample: 4,
        power_iters: iters,
    };
    rsvd(a, r, &opts, None, rng).u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{singular_values, top_singular_vectors};

    fn separated_spectrum(
        m: usize,
        n: usize,
        k: usize,
        noise: f32,
        rng: &mut Pcg,
    ) -> Matrix {
        let u = Matrix::randn(m, k, 1.0, rng);
        let v = Matrix::randn(k, n, 1.0, rng);
        let mut a = matmul(&u, &v);
        a.add_scaled_in_place(noise, &Matrix::randn(m, n, 1.0, rng));
        a
    }

    /// ‖PᵀQ‖ Gram ≈ I ⇔ the two orthonormal bases span the same space.
    fn assert_same_subspace(p: &Matrix, q: &Matrix, tol: f32, ctx: &str) {
        assert_eq!(p.shape(), q.shape(), "{ctx}: shape");
        let cross = matmul_tn(p, q);
        let gram = matmul_tn(&cross, &cross);
        let err = gram.max_abs_diff(&Matrix::eye(p.cols));
        assert!(err < tol, "{ctx}: subspace mismatch {err}");
    }

    #[test]
    fn randomized_matches_exact_on_separated_spectrum() {
        let mut rng = Pcg::new(5);
        let a = separated_spectrum(40, 80, 3, 0.01, &mut rng);
        let exact = top_singular_vectors(&a, 3);
        let rand = top_singular_vectors_randomized(&a, 3, 2, &mut rng);
        assert_same_subspace(&exact, &rand, 1e-2, "cold rsvd");
        let qtq = matmul_tn(&rand, &rand);
        assert!(qtq.max_abs_diff(&Matrix::eye(3)) < 1e-4);
    }

    #[test]
    fn randomized_handles_rank_clamp() {
        let mut rng = Pcg::new(6);
        let a = Matrix::randn(6, 30, 1.0, &mut rng);
        let q = top_singular_vectors_randomized(&a, 100, 1, &mut rng);
        assert_eq!(q.shape(), (6, 6));
    }

    #[test]
    fn rsvd_values_descend_and_match_exact() {
        let mut rng = Pcg::new(7);
        let a = separated_spectrum(30, 50, 4, 0.01, &mut rng);
        let svd = rsvd(&a, 4, &RsvdOpts::default(), None, &mut rng);
        assert_eq!(svd.u.shape(), (30, 4));
        assert_eq!(svd.vt.shape(), (4, 50));
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let exact = singular_values(&a);
        for (i, (&got, &want)) in svd.s.iter().zip(&exact).enumerate() {
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want),
                "σ{i}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn warm_start_tracks_drifting_subspace_in_one_iteration() {
        let mut rng = Pcg::new(8);
        let a = separated_spectrum(40, 64, 4, 0.01, &mut rng);
        let cold = rsvd(&a, 4, &RsvdOpts::default(), None, &mut rng);
        // Small drift: the dominant subspace moves slightly.
        let mut a2 = a.clone();
        a2.add_scaled_in_place(0.05, &Matrix::randn(40, 64, 1.0, &mut rng));
        let warm_opts = RsvdOpts {
            oversample: 4,
            power_iters: 1,
        };
        let warm = rsvd(&a2, 4, &warm_opts, Some(&cold.u), &mut rng);
        let exact = top_singular_vectors(&a2, 4);
        assert_same_subspace(&exact, &warm.u, 1e-2, "warm rsvd");
    }

    #[test]
    fn mismatched_warm_basis_is_ignored() {
        let mut rng = Pcg::new(9);
        let a = separated_spectrum(20, 40, 3, 0.01, &mut rng);
        let bogus = Matrix::randn(7, 3, 1.0, &mut rng); // wrong row count
        let svd = rsvd(&a, 3, &RsvdOpts::default(), Some(&bogus), &mut rng);
        let exact = top_singular_vectors(&a, 3);
        assert_same_subspace(&exact, &svd.u, 1e-2, "ignored warm");
    }

    #[test]
    fn degenerate_inputs_stay_finite() {
        let mut rng = Pcg::new(10);
        let zero = Matrix::zeros(12, 20);
        let svd = rsvd(&zero, 3, &RsvdOpts::default(), None, &mut rng);
        assert!(svd.u.is_finite());
        assert!(svd.s.iter().all(|v| v.abs() < 1e-6));
        // Warm basis wider than the sketch width is truncated, not a panic.
        let a = separated_spectrum(10, 16, 2, 0.01, &mut rng);
        let wide = Matrix::randn(10, 10, 1.0, &mut rng);
        let svd = rsvd(&a, 2, &RsvdOpts::default(), Some(&wide), &mut rng);
        assert_eq!(svd.u.shape(), (10, 2));
        assert!(svd.u.is_finite());
    }
}
