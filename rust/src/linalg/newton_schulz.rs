//! Newton–Schulz `msign` — the native (L3) twin of the L1 Pallas kernel.
//!
//! Same quintic iteration and coefficients as
//! `python/compile/kernels/newton_schulz.py`; cross-checked against the
//! HLO artifact in `rust/tests/runtime_roundtrip.rs`.
//!
//! The iteration is GEMM-bound end to end (three products per step), so
//! the hot-loop form is [`newton_schulz_into`]: every product lands in
//! a caller-owned [`NsWorkspace`] buffer via the packed `gemm` kernels —
//! zero allocations per call once the workspace is warm. The optimizers
//! (Muon, GaLore-Muon, GUM) hold one workspace each and reuse it across
//! blocks and steps.

use super::{
    fro_norm, matmul_into, matmul_nt_into, svd_thin, Matrix,
};

/// Quintic coefficients from Jordan et al. (2024).
pub const NS_COEFFS: (f32, f32, f32) = (3.4445, -4.7750, 2.0315);
/// Default iteration count used by Muon.
pub const NS_STEPS: usize = 5;

const EPS: f32 = 1e-7;

/// Reusable buffers for the Newton–Schulz iteration: the oriented
/// iterate and the three per-step products. All grow on demand and are
/// reused across calls (`Matrix::resize` keeps the allocations).
#[derive(Debug, Default)]
pub struct NsWorkspace {
    x: Matrix,
    gram: Matrix,
    gx: Matrix,
    ggx: Matrix,
}

impl NsWorkspace {
    pub fn new() -> NsWorkspace {
        NsWorkspace {
            x: Matrix::zeros(0, 0),
            gram: Matrix::zeros(0, 0),
            gx: Matrix::zeros(0, 0),
            ggx: Matrix::zeros(0, 0),
        }
    }
}

/// Approximate `msign(G) = U Vᵀ` via quintic Newton–Schulz.
///
/// Wide/tall handling matches the reference Muon implementation: the
/// iteration runs on the orientation with rows ≤ cols so the Gram matrix
/// is the small side.
pub fn newton_schulz(g: &Matrix, steps: usize) -> Matrix {
    let mut ws = NsWorkspace::new();
    let mut out = Matrix::zeros(0, 0);
    newton_schulz_into(g, steps, &mut ws, &mut out);
    out
}

/// [`newton_schulz`] into a caller-owned output with workspace reuse —
/// the per-step form for optimizer hot loops. `out` is resized to
/// `g.shape()`.
pub fn newton_schulz_into(
    g: &Matrix,
    steps: usize,
    ws: &mut NsWorkspace,
    out: &mut Matrix,
) {
    let (a, b, c) = NS_COEFFS;
    let transposed = g.rows > g.cols;
    if transposed {
        g.transpose_into(&mut ws.x);
    } else {
        ws.x.copy_from(g);
    }
    let norm = fro_norm(&ws.x) + EPS;
    ws.x.scale_in_place(1.0 / norm);
    for _ in 0..steps {
        matmul_nt_into(&ws.x, &ws.x, &mut ws.gram); // A = X Xᵀ (small side)
        matmul_into(&ws.gram, &ws.x, &mut ws.gx); // A X
        matmul_into(&ws.gram, &ws.gx, &mut ws.ggx); // A² X
        // x = a*x + b*gx + c*ggx
        for ((xv, &gxv), &ggxv) in ws
            .x
            .data
            .iter_mut()
            .zip(&ws.gx.data)
            .zip(&ws.ggx.data)
        {
            *xv = a * *xv + b * gxv + c * ggxv;
        }
    }
    if transposed {
        ws.x.transpose_into(out);
    } else {
        out.copy_from(&ws.x);
    }
}

/// Exact `msign` via thin SVD (Assumption 4 in the paper; test oracle).
pub fn msign_exact(g: &Matrix) -> Matrix {
    let svd = svd_thin(g);
    super::matmul(&svd.u, &svd.vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, matmul_tn, singular_values};
    use crate::rng::Pcg;

    #[test]
    fn singular_values_pushed_toward_one() {
        let mut rng = Pcg::new(0);
        let g = Matrix::randn(24, 24, 1.0, &mut rng);
        let out = newton_schulz(&g, 8);
        let s = singular_values(&out);
        for &v in &s {
            assert!(v > 0.4 && v < 1.6, "sv {v}");
        }
    }

    #[test]
    fn directionally_matches_exact_msign() {
        let mut rng = Pcg::new(1);
        for (m, n) in [(16, 32), (32, 16), (20, 20)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let ns = newton_schulz(&g, NS_STEPS);
            let exact = msign_exact(&g);
            let num: f32 = ns
                .data
                .iter()
                .zip(&exact.data)
                .map(|(a, b)| a * b)
                .sum();
            let cos = num / (fro_norm(&ns) * fro_norm(&exact));
            assert!(cos > 0.98, "({m},{n}) cos {cos}");
        }
    }

    #[test]
    fn into_variant_matches_allocating_across_shapes() {
        // Workspace reuse across differently-shaped blocks (the
        // optimizer pattern) must not leak state between calls.
        let mut rng = Pcg::new(5);
        let mut ws = NsWorkspace::new();
        let mut out = Matrix::zeros(0, 0);
        for (m, n) in [(12usize, 20usize), (20, 12), (7, 7), (16, 48)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            newton_schulz_into(&g, NS_STEPS, &mut ws, &mut out);
            let want = newton_schulz(&g, NS_STEPS);
            assert_eq!(out.shape(), (m, n));
            assert_eq!(out.data, want.data, "({m},{n})");
        }
    }

    #[test]
    fn msign_exact_is_orthogonal() {
        let mut rng = Pcg::new(2);
        let g = Matrix::randn(10, 25, 1.0, &mut rng);
        let ms = msign_exact(&g);
        let mtm = matmul_tn(&ms, &ms);
        // For m < n, msign has orthonormal rows: M Mᵀ = I_m.
        let mmt = matmul_nt(&ms, &ms);
        assert!(mmt.max_abs_diff(&Matrix::eye(10)) < 1e-3);
        let _ = mtm;
    }

    #[test]
    fn scale_invariance() {
        let mut rng = Pcg::new(3);
        let g = Matrix::randn(12, 18, 1.0, &mut rng);
        let a = newton_schulz(&g, NS_STEPS);
        let b = newton_schulz(&g.scaled(250.0), NS_STEPS);
        assert!(a.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn commutes_with_orthonormal_projection() {
        // Property II (paper Lemma 1): NS(P X) = P NS(X) for column-
        // orthonormal P. This is the key algebra behind GUM's
        // unbiasedness.
        let mut rng = Pcg::new(4);
        let p = crate::linalg::random_orthonormal(24, 8, &mut rng);
        let x = Matrix::randn(8, 30, 1.0, &mut rng);
        let left = newton_schulz(&matmul(&p, &x), NS_STEPS);
        let right = matmul(&p, &newton_schulz(&x, NS_STEPS));
        assert!(left.max_abs_diff(&right) < 1e-3);
    }
}
