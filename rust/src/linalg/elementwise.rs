//! Fused single-pass elementwise optimizer kernels — the L3 per-step
//! arithmetic behind every momentum / Adam / residual update.
//!
//! Each kernel exists because some optimizer step path used to make
//! several scalar passes over parameter-block-sized buffers:
//!
//! | kernel | fuses | used by |
//! |---|---|---|
//! | [`axpby`] | decay + (scaled) accumulate `x ← a·x + b·y` | every momentum update (Muon, GUM, GaLore) |
//! | [`add_scaled`] | projected-update apply `x ← x + a·y` | every weight update |
//! | [`decay_accumulate2`] | `m ← β·m + a·x + b·y` | GUM's compensated full-rank momentum (both variants) |
//! | [`residual_add`] | `w ← w + c·(g − r)` | Fira's scaled-residual weight update |
//! | [`adam_update`] | both moment updates + bias-corrected step | GaLore-Adam / Fira projected moments |
//! | [`adam_apply`] | moments + decoupled decay + weight write | `DenseAdamW` (dense blocks everywhere) |
//!
//! Dispatch follows the GEMM microkernel convention: one generic body
//! per kernel, compiled per ISA level — AVX-512F/BW (16 f32 lanes) and
//! AVX2+FMA (8 lanes) specializations selected by the cached probe in
//! [`super::isa`] (shared with `linalg::gemm` and `linalg::lowp`), and
//! a portable fallback that is also the only path off x86-64. The
//! probe is global, so every thread runs identical arithmetic.
//!
//! Large buffers fan out over the worker pool ([`parallel_chunks`]).
//! Every output element is a pure function of its index, so results are
//! **bit-identical under any `GUM_THREADS`** and under any chunk split,
//! *within a fixed ISA path* (asserted by
//! `rust/tests/elementwise_kernels.rs`; the cross-path contract lives
//! in `linalg::isa`).

use super::isa;
use crate::thread::parallel_chunks;

/// Minimum elements per chunk before pool dispatch pays off: elementwise
/// passes are memory-bound, so only parameter-block-sized buffers (≥2
/// chunks of this) are worth fanning out.
const PAR_MIN: usize = 1 << 15;

// ---------------------------------------------------------------------------
// CPU probe + dispatch (see linalg::isa for the cached probe + env
// overrides GUM_FORCE_PORTABLE / GUM_FORCE_AVX2)
// ---------------------------------------------------------------------------

/// Force the portable (non-SIMD-specialized) kernel bodies, returning
/// whether the portable cap was previously installed — the benches'
/// A/B switch (`benches/optim_step.rs`) and the cross-path agreement
/// tests use this. Process-global: callers that toggle it must
/// serialize (tests hold a lock) and restore the prior value. Kept
/// here (delegating to [`isa::force_portable`]) because the cap also
/// governs the gemm and lowp dispatchers.
pub fn force_portable(on: bool) -> bool {
    isa::force_portable(on)
}

// ---------------------------------------------------------------------------
// Parallel fan-out plumbing
// ---------------------------------------------------------------------------

struct SendMutPtr(*mut f32);
unsafe impl Sync for SendMutPtr {}
unsafe impl Send for SendMutPtr {}

struct SendConstPtr(*const f32);
unsafe impl Sync for SendConstPtr {}
unsafe impl Send for SendConstPtr {}

/// Re-slice a mutable base pointer to one chunk's exclusive range.
///
/// SAFETY: callers pass disjoint `[start, end)` ranges per chunk (the
/// `parallel_chunks` contract) and the owning slice outlives the
/// dispatch (`parallel_chunks` blocks until every chunk retires).
unsafe fn chunk_mut<'a>(p: *mut f32, start: usize, end: usize) -> &'a mut [f32] {
    unsafe { std::slice::from_raw_parts_mut(p.add(start), end - start) }
}

/// Immutable sibling of [`chunk_mut`]. SAFETY: as above (shared reads).
unsafe fn chunk_ref<'a>(p: *const f32, start: usize, end: usize) -> &'a [f32] {
    unsafe { std::slice::from_raw_parts(p.add(start), end - start) }
}

// ---------------------------------------------------------------------------
// Kernel bodies (generic over FMA, compiled twice — see gemm.rs)
// ---------------------------------------------------------------------------

#[inline(always)]
fn fma<const FMA: bool>(a: f32, b: f32, c: f32) -> f32 {
    if FMA {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

#[inline(always)]
fn axpby_body<const FMA: bool>(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xv, &yv) in x.iter_mut().zip(y) {
        *xv = fma::<FMA>(b, yv, a * *xv);
    }
}

#[inline(always)]
fn add_scaled_body<const FMA: bool>(x: &mut [f32], a: f32, y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (xv, &yv) in x.iter_mut().zip(y) {
        *xv = fma::<FMA>(a, yv, *xv);
    }
}

#[inline(always)]
fn decay_accumulate2_body<const FMA: bool>(
    m: &mut [f32],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
) {
    debug_assert!(m.len() == x.len() && m.len() == y.len());
    for ((mv, &xv), &yv) in m.iter_mut().zip(x).zip(y) {
        let acc = fma::<FMA>(a, xv, beta * *mv);
        *mv = fma::<FMA>(b, yv, acc);
    }
}

#[inline(always)]
fn residual_add_body<const FMA: bool>(w: &mut [f32], c: f32, g: &[f32], r: &[f32]) {
    debug_assert!(w.len() == g.len() && w.len() == r.len());
    for ((wv, &gv), &rv) in w.iter_mut().zip(g).zip(r) {
        *wv = fma::<FMA>(c, gv - rv, *wv);
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adam_update_body<const FMA: bool>(
    upd: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    debug_assert!(
        upd.len() == g.len() && upd.len() == m.len() && upd.len() == v.len()
    );
    for (((uv, &gv), mv), vv) in
        upd.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
    {
        let m_new = fma::<FMA>(b1, *mv, (1.0 - b1) * gv);
        let v_new = fma::<FMA>(b2, *vv, (1.0 - b2) * gv * gv);
        *mv = m_new;
        *vv = v_new;
        *uv = (m_new / bc1) / ((v_new / bc2).sqrt() + eps);
    }
}

#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn adam_apply_body<const FMA: bool>(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    debug_assert!(
        w.len() == g.len() && w.len() == m.len() && w.len() == v.len()
    );
    for (((wv, &gv), mv), vv) in
        w.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
    {
        let m_new = fma::<FMA>(b1, *mv, (1.0 - b1) * gv);
        let v_new = fma::<FMA>(b2, *vv, (1.0 - b2) * gv * gv);
        *mv = m_new;
        *vv = v_new;
        let mhat = m_new / bc1;
        let vhat = v_new / bc2;
        let mut x = *wv;
        if wd > 0.0 {
            x -= lr * wd * x;
        }
        *wv = x - lr * mhat / (vhat.sqrt() + eps);
    }
}

// ---------------------------------------------------------------------------
// AVX2+FMA specializations (same bodies, 8-lane f32 + vfmadd codegen)
// ---------------------------------------------------------------------------

/// SAFETY (all `_avx2` fns): callers must have verified avx2 + fma
/// support — the [`isa::level`] match gates every call site.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpby(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
        axpby_body::<true>(a, x, b, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_scaled(x: &mut [f32], a: f32, y: &[f32]) {
        add_scaled_body::<true>(x, a, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn decay_accumulate2(
        m: &mut [f32],
        beta: f32,
        a: f32,
        x: &[f32],
        b: f32,
        y: &[f32],
    ) {
        decay_accumulate2_body::<true>(m, beta, a, x, b, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn residual_add(w: &mut [f32], c: f32, g: &[f32], r: &[f32]) {
        residual_add_body::<true>(w, c, g, r)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn adam_update(
        upd: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    ) {
        adam_update_body::<true>(upd, g, m, v, b1, b2, bc1, bc2, eps)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn adam_apply(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
        lr: f32,
        wd: f32,
    ) {
        adam_apply_body::<true>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
    }
}

// ---------------------------------------------------------------------------
// AVX-512F/BW specializations (same bodies again, 16-lane f32 codegen)
// ---------------------------------------------------------------------------

/// SAFETY (all `avx512::*` fns): callers must have verified avx512f +
/// avx512bw support — the [`isa::level`] match gates every call site.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use super::*;

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn axpby(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
        axpby_body::<true>(a, x, b, y)
    }

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn add_scaled(x: &mut [f32], a: f32, y: &[f32]) {
        add_scaled_body::<true>(x, a, y)
    }

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn decay_accumulate2(
        m: &mut [f32],
        beta: f32,
        a: f32,
        x: &[f32],
        b: f32,
        y: &[f32],
    ) {
        decay_accumulate2_body::<true>(m, beta, a, x, b, y)
    }

    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn residual_add(w: &mut [f32], c: f32, g: &[f32], r: &[f32]) {
        residual_add_body::<true>(w, c, g, r)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn adam_update(
        upd: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    ) {
        adam_update_body::<true>(upd, g, m, v, b1, b2, bc1, bc2, eps)
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx512f", enable = "avx512bw")]
    pub unsafe fn adam_apply(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
        lr: f32,
        wd: f32,
    ) {
        adam_apply_body::<true>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
    }
}

// ---------------------------------------------------------------------------
// Serial dispatchers (probe once, then straight-line)
// ---------------------------------------------------------------------------

fn axpby_serial(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => return unsafe { avx512::axpby(a, x, b, y) },
        isa::IsaLevel::Avx2 => return unsafe { avx2::axpby(a, x, b, y) },
        isa::IsaLevel::Portable => {}
    }
    axpby_body::<false>(a, x, b, y)
}

fn add_scaled_serial(x: &mut [f32], a: f32, y: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => return unsafe { avx512::add_scaled(x, a, y) },
        isa::IsaLevel::Avx2 => return unsafe { avx2::add_scaled(x, a, y) },
        isa::IsaLevel::Portable => {}
    }
    add_scaled_body::<false>(x, a, y)
}

fn decay_accumulate2_serial(
    m: &mut [f32],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe { avx512::decay_accumulate2(m, beta, a, x, b, y) }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe { avx2::decay_accumulate2(m, beta, a, x, b, y) }
        }
        isa::IsaLevel::Portable => {}
    }
    decay_accumulate2_body::<false>(m, beta, a, x, b, y)
}

fn residual_add_serial(w: &mut [f32], c: f32, g: &[f32], r: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe { avx512::residual_add(w, c, g, r) }
        }
        isa::IsaLevel::Avx2 => return unsafe { avx2::residual_add(w, c, g, r) },
        isa::IsaLevel::Portable => {}
    }
    residual_add_body::<false>(w, c, g, r)
}

#[allow(clippy::too_many_arguments)]
fn adam_update_serial(
    upd: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe {
                avx512::adam_update(upd, g, m, v, b1, b2, bc1, bc2, eps)
            }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe {
                avx2::adam_update(upd, g, m, v, b1, b2, bc1, bc2, eps)
            }
        }
        isa::IsaLevel::Portable => {}
    }
    adam_update_body::<false>(upd, g, m, v, b1, b2, bc1, bc2, eps)
}

#[allow(clippy::too_many_arguments)]
fn adam_apply_serial(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    #[cfg(target_arch = "x86_64")]
    match isa::level() {
        // SAFETY: the probe verified the respective feature sets.
        isa::IsaLevel::Avx512 => {
            return unsafe {
                avx512::adam_apply(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
            }
        }
        isa::IsaLevel::Avx2 => {
            return unsafe {
                avx2::adam_apply(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
            }
        }
        isa::IsaLevel::Portable => {}
    }
    adam_apply_body::<false>(w, g, m, v, b1, b2, bc1, bc2, eps, lr, wd)
}

// ---------------------------------------------------------------------------
// Public entry points (threaded over the pool for block-sized buffers)
// ---------------------------------------------------------------------------

/// `x ← a·x` (plain scale: already a single pass; no FMA variant).
pub fn scale(x: &mut [f32], a: f32) {
    let xp = SendMutPtr(x.as_mut_ptr());
    parallel_chunks(x.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; x outlives the blocking dispatch.
        let xs = unsafe { chunk_mut(xp.0, s, e) };
        for v in xs {
            *v *= a;
        }
    });
}

/// Momentum decay + scaled accumulate: `x ← a·x + b·y` in one pass.
pub fn axpby(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
    assert_eq!(x.len(), y.len(), "axpby length mismatch");
    let xp = SendMutPtr(x.as_mut_ptr());
    let yp = SendConstPtr(y.as_ptr());
    parallel_chunks(x.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (xs, ys) = unsafe { (chunk_mut(xp.0, s, e), chunk_ref(yp.0, s, e)) };
        axpby_serial(a, xs, b, ys);
    });
}

/// Scaled update apply: `x ← x + a·y` in one pass.
pub fn add_scaled(x: &mut [f32], a: f32, y: &[f32]) {
    assert_eq!(x.len(), y.len(), "add_scaled length mismatch");
    let xp = SendMutPtr(x.as_mut_ptr());
    let yp = SendConstPtr(y.as_ptr());
    parallel_chunks(x.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (xs, ys) = unsafe { (chunk_mut(xp.0, s, e), chunk_ref(yp.0, s, e)) };
        add_scaled_serial(xs, a, ys);
    });
}

/// Fused momentum decay + two scaled accumulates:
/// `m ← β·m + a·x + b·y` — GUM's compensated full-rank momentum
/// (`a·G + b·PPᵀG` covers both the Paper and Scaled variants) in one
/// pass instead of a scale + two axpby sweeps.
pub fn decay_accumulate2(
    m: &mut [f32],
    beta: f32,
    a: f32,
    x: &[f32],
    b: f32,
    y: &[f32],
) {
    assert!(
        m.len() == x.len() && m.len() == y.len(),
        "decay_accumulate2 length mismatch"
    );
    let mp = SendMutPtr(m.as_mut_ptr());
    let xp = SendConstPtr(x.as_ptr());
    let yp = SendConstPtr(y.as_ptr());
    parallel_chunks(m.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ms, xs, ys) = unsafe {
            (chunk_mut(mp.0, s, e), chunk_ref(xp.0, s, e), chunk_ref(yp.0, s, e))
        };
        decay_accumulate2_serial(ms, beta, a, xs, b, ys);
    });
}

/// Residual-scaled add: `w ← w + c·(g − r)` — Fira's full-rank residual
/// step applied straight from the gradient and the lifted low-rank
/// reconstruction, with no materialized residual buffer.
pub fn residual_add(w: &mut [f32], c: f32, g: &[f32], r: &[f32]) {
    assert!(
        w.len() == g.len() && w.len() == r.len(),
        "residual_add length mismatch"
    );
    let wp = SendMutPtr(w.as_mut_ptr());
    let gp = SendConstPtr(g.as_ptr());
    let rp = SendConstPtr(r.as_ptr());
    parallel_chunks(w.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ws, gs, rs) = unsafe {
            (chunk_mut(wp.0, s, e), chunk_ref(gp.0, s, e), chunk_ref(rp.0, s, e))
        };
        residual_add_serial(ws, c, gs, rs);
    });
}

/// Fused Adam moment update + bias-corrected step direction:
/// `m ← β₁m + (1−β₁)g`, `v ← β₂v + (1−β₂)g²`,
/// `upd ← (m/bc₁) / (√(v/bc₂) + ε)` — one pass over four buffers
/// (GaLore-Adam / Fira projected moments).
#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    upd: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
) {
    assert!(
        upd.len() == g.len() && upd.len() == m.len() && upd.len() == v.len(),
        "adam_update length mismatch"
    );
    let up = SendMutPtr(upd.as_mut_ptr());
    let gp = SendConstPtr(g.as_ptr());
    let mp = SendMutPtr(m.as_mut_ptr());
    let vp = SendMutPtr(v.as_mut_ptr());
    parallel_chunks(upd.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (us, gs, ms, vs) = unsafe {
            (
                chunk_mut(up.0, s, e),
                chunk_ref(gp.0, s, e),
                chunk_mut(mp.0, s, e),
                chunk_mut(vp.0, s, e),
            )
        };
        adam_update_serial(us, gs, ms, vs, b1, b2, bc1, bc2, eps);
    });
}

/// Fused AdamW step applied directly to the weights: moment updates,
/// bias correction, decoupled weight decay, and the weight write in one
/// pass over four buffers (`DenseAdamW`'s whole step).
#[allow(clippy::too_many_arguments)]
pub fn adam_apply(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
) {
    assert!(
        w.len() == g.len() && w.len() == m.len() && w.len() == v.len(),
        "adam_apply length mismatch"
    );
    let wp = SendMutPtr(w.as_mut_ptr());
    let gp = SendConstPtr(g.as_ptr());
    let mp = SendMutPtr(m.as_mut_ptr());
    let vp = SendMutPtr(v.as_mut_ptr());
    parallel_chunks(w.len(), PAR_MIN, |s, e| {
        // SAFETY: disjoint chunks; operands outlive the dispatch.
        let (ws, gs, ms, vs) = unsafe {
            (
                chunk_mut(wp.0, s, e),
                chunk_ref(gp.0, s, e),
                chunk_mut(mp.0, s, e),
                chunk_mut(vp.0, s, e),
            )
        };
        adam_apply_serial(ws, gs, ms, vs, b1, b2, bc1, bc2, eps, lr, wd);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, k: f32) -> Vec<f32> {
        (0..n).map(|i| ((i % 17) as f32 - 8.0) * k).collect()
    }

    #[test]
    fn axpby_matches_f64_reference() {
        for n in [0usize, 1, 7, 63, 64, 1025] {
            let mut x = seq(n, 0.3);
            let y = seq(n, -0.7);
            let want: Vec<f32> = x
                .iter()
                .zip(&y)
                .map(|(&a, &b)| (1.5f64 * a as f64 + 0.25f64 * b as f64) as f32)
                .collect();
            axpby(1.5, &mut x, 0.25, &y);
            for (got, want) in x.iter().zip(&want) {
                assert!((got - want).abs() <= 1e-5 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn add_scaled_of_one_is_exact_sum() {
        // The pairwise tree sum relies on `x + 1.0·y` being the exact
        // f32 addition.
        let mut x = vec![0.1f32, -2.5, 3.25];
        let y = vec![1.5f32, 0.5, -0.25];
        let want: Vec<f32> =
            x.iter().zip(&y).map(|(&a, &b)| a + b).collect();
        add_scaled(&mut x, 1.0, &y);
        assert_eq!(x, want);
    }

    #[test]
    fn decay_accumulate2_matches_composition() {
        let n = 129;
        let mut m = seq(n, 0.2);
        let x = seq(n, 1.0);
        let y = seq(n, -0.4);
        let mut want = m.clone();
        for i in 0..n {
            want[i] =
                (0.9f64 * want[i] as f64 + 2.0 * x[i] as f64 - 0.5 * y[i] as f64)
                    as f32;
        }
        decay_accumulate2(&mut m, 0.9, 2.0, &x, -0.5, &y);
        for (got, want) in m.iter().zip(&want) {
            assert!((got - want).abs() <= 1e-5 * want.abs().max(1.0));
        }
    }

    #[test]
    fn residual_add_matches_composition() {
        let n = 77;
        let mut w = seq(n, 0.1);
        let g = seq(n, 0.9);
        let r = seq(n, 0.3);
        let mut want = w.clone();
        for i in 0..n {
            want[i] += -0.25 * (g[i] - r[i]);
        }
        residual_add(&mut w, -0.25, &g, &r);
        for (got, want) in w.iter().zip(&want) {
            assert!((got - want).abs() <= 1e-5);
        }
    }

    #[test]
    fn adam_kernels_match_scalar_reference() {
        let n = 200;
        let g = seq(n, 0.8);
        let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 0.05, 0.01);
        let (bc1, bc2) = (1.0 - b1.powi(3), 1.0 - b2.powi(3));

        // adam_update vs the old zip-loop semantics.
        let mut m = seq(n, 0.1);
        let mut v: Vec<f32> = seq(n, 0.1).iter().map(|x| x * x).collect();
        let (mut mr, mut vr) = (m.clone(), v.clone());
        let mut upd = vec![0.0f32; n];
        let mut upd_ref = vec![0.0f32; n];
        for i in 0..n {
            mr[i] = b1 * mr[i] + (1.0 - b1) * g[i];
            vr[i] = b2 * vr[i] + (1.0 - b2) * g[i] * g[i];
            upd_ref[i] = (mr[i] / bc1) / ((vr[i] / bc2).sqrt() + eps);
        }
        adam_update(&mut upd, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps);
        for i in 0..n {
            assert!((upd[i] - upd_ref[i]).abs() <= 2e-5 * upd_ref[i].abs().max(1.0));
            assert!((m[i] - mr[i]).abs() <= 1e-6 * mr[i].abs().max(1.0));
        }

        // adam_apply vs the old DenseAdamW loop.
        let mut w = seq(n, 0.5);
        let mut wr = w.clone();
        let mut m = seq(n, 0.1);
        let mut v: Vec<f32> = seq(n, 0.1).iter().map(|x| x * x).collect();
        let (mut mr, mut vr) = (m.clone(), v.clone());
        for i in 0..n {
            mr[i] = b1 * mr[i] + (1.0 - b1) * g[i];
            vr[i] = b2 * vr[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = mr[i] / bc1;
            let vhat = vr[i] / bc2;
            let mut x = wr[i];
            if wd > 0.0 {
                x -= lr * wd * x;
            }
            wr[i] = x - lr * mhat / (vhat.sqrt() + eps);
        }
        adam_apply(
            &mut w, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps, lr, wd,
        );
        for i in 0..n {
            assert!((w[i] - wr[i]).abs() <= 2e-5 * wr[i].abs().max(1.0));
        }
    }

    #[test]
    fn scale_is_exact() {
        let mut x = seq(100, 0.5);
        let want: Vec<f32> = x.iter().map(|v| v * 2.5).collect();
        scale(&mut x, 2.5);
        assert_eq!(x, want);
    }
}
