//! Thin SVD via symmetric Jacobi eigendecomposition of the Gram matrix.
//!
//! GaLore's projector needs the top-r *left* singular vectors of the
//! gradient G (m×n). We eigendecompose the smaller Gram side in f64
//! (G·Gᵀ when m ≤ n, else Gᵀ·G), then recover the other factor by one
//! GEMM. Cyclic Jacobi converges quadratically and is embarrassingly
//! stable for the m ≤ ~1k blocks this system handles.

use crate::thread::parallel_chunks;

use super::{matmul, matmul_tn, Matrix};

/// Thin SVD result: `a ≈ u · diag(s) · vt` with `u` m×p, `vt` p×n,
/// p = min(m, n); singular values descending.
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub vt: Matrix,
}

/// Symmetric eigendecomposition (cyclic Jacobi, f64 accumulation).
/// Returns (eigenvalues desc, eigenvectors as columns of a row-major
/// matrix) for a symmetric n×n input given in f64.
fn jacobi_eigh(mut a: Vec<f64>, n: usize) -> (Vec<f64>, Vec<f64>) {
    // v = identity
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;

    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm for convergence check.
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[idx(i, j)] * a[idx(i, j)];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + frob64(&a, n)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[idx(p, p)];
                let aqq = a[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for k in 0..n {
                    let akp = a[idx(k, p)];
                    let akq = a[idx(k, q)];
                    a[idx(k, p)] = c * akp - s * akq;
                    a[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[idx(p, k)];
                    let aqk = a[idx(q, k)];
                    a[idx(p, k)] = c * apk - s * aqk;
                    a[idx(q, k)] = s * apk + c * aqk;
                }
                // Accumulate rotations into V (columns are eigenvectors).
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract eigenvalues, sort descending with eigenvectors.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| a[idx(i, i)]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = vec![0.0f64; n * n];
    for (new_j, &old_j) in order.iter().enumerate() {
        for k in 0..n {
            sorted_vecs[idx(k, new_j)] = v[idx(k, old_j)];
        }
    }
    (sorted_vals, sorted_vecs)
}

fn frob64(a: &[f64], n: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n * n {
        s += a[i] * a[i];
    }
    s.sqrt()
}

/// Gram matrix of the smaller side, accumulated in f64.
fn gram_small(a: &Matrix) -> (Vec<f64>, usize, bool) {
    let (m, n) = a.shape();
    let left = m <= n; // gram = A Aᵀ (m×m) if left else Aᵀ A (n×n)
    let p = m.min(n);
    let mut g = vec![0.0f64; p * p];
    if left {
        let out = SendMut(g.as_mut_ptr());
        parallel_chunks(p, 4, |r0, r1| {
            let out = &out;
            for i in r0..r1 {
                let ri = a.row(i);
                for j in i..p {
                    let rj = a.row(j);
                    let mut s = 0.0f64;
                    for k in 0..n {
                        s += ri[k] as f64 * rj[k] as f64;
                    }
                    unsafe {
                        *out.0.add(i * p + j) = s;
                        *out.0.add(j * p + i) = s;
                    }
                }
            }
        });
    } else {
        // Aᵀ A: accumulate over rows (streaming reads of A).
        for k in 0..m {
            let rk = a.row(k);
            for i in 0..p {
                let aki = rk[i] as f64;
                if aki == 0.0 {
                    continue;
                }
                for j in i..p {
                    g[i * p + j] += aki * rk[j] as f64;
                }
            }
        }
        for i in 0..p {
            for j in 0..i {
                g[i * p + j] = g[j * p + i];
            }
        }
    }
    (g, p, left)
}

struct SendMut<T>(*mut T);
unsafe impl<T> Sync for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}

/// Thin SVD. For m ≤ n: eigh(G Gᵀ) → U, then Vᵀ = Σ⁻¹ Uᵀ G.
pub fn svd_thin(a: &Matrix) -> Svd {
    let (m, _n) = a.shape();
    let (g, p, left) = gram_small(a);
    let (evals, evecs) = jacobi_eigh(g, p);
    let s: Vec<f32> = evals
        .iter()
        .map(|&v| (v.max(0.0)).sqrt() as f32)
        .collect();

    // Eigenvector matrix (p×p, columns = vectors) as f32 row-major.
    let w = Matrix::from_vec(
        p,
        p,
        evecs.iter().map(|&v| v as f32).collect(),
    );

    if left {
        // U = W (m×m = p×p), Vᵀ = Σ⁻¹ Uᵀ A (p×n).
        let ut_a = matmul_tn(&w, a);
        let mut vt = ut_a;
        for (i, &si) in s.iter().enumerate() {
            let inv = if si > 1e-12 { 1.0 / si } else { 0.0 };
            for val in vt.row_mut(i) {
                *val *= inv;
            }
        }
        Svd { u: w, s, vt }
    } else {
        // V = W (n×p), U = A V Σ⁻¹ (m×p), Vᵀ = Wᵀ.
        let av = matmul(a, &w);
        let mut u = av;
        for i in 0..m {
            for (j, &sj) in s.iter().enumerate() {
                let inv = if sj > 1e-12 { 1.0 / sj } else { 0.0 };
                u.data[i * p + j] *= inv;
            }
        }
        Svd {
            u,
            s,
            vt: w.transpose(),
        }
    }
}

/// Top-r left singular vectors (GaLore projector P = U[:, :r]), exact.
pub fn top_singular_vectors(a: &Matrix, r: usize) -> Matrix {
    let p = a.rows.min(a.cols).min(r);
    svd_thin(a).u.left_cols(p)
}

/// Top-r left singular vectors via randomized subspace iteration
/// (Halko–Martinsson–Tropp): Y = A·Ω, then power iterations
/// Q ← orth(A·(Aᵀ·Q)), finishing with an exact SVD of the small
/// projected matrix QᵀA. ~50× faster than Jacobi for the projector
/// refresh (§Perf) at equivalent subspace quality for the separated
/// spectra GaLore exploits.
pub fn top_singular_vectors_randomized(
    a: &Matrix,
    r: usize,
    iters: usize,
    rng: &mut crate::rng::Pcg,
) -> Matrix {
    use super::{matmul, matmul_tn, qr_orthonormal};
    let (m, n) = a.shape();
    let side = m.min(n);
    let r = r.min(side);
    // Oversampled sketch width.
    let p = (r + 4).min(side);
    // Y = A·Ω (m×p).
    let omega = Matrix::randn(n, p, 1.0, rng);
    let mut q = qr_orthonormal(&matmul(a, &omega));
    for _ in 0..iters {
        // Q ← orth(A Aᵀ Q) without forming A Aᵀ.
        let atq = matmul_tn(a, &q); // n×p
        q = qr_orthonormal(&matmul(a, &atq));
    }
    // Rotate Q onto the singular basis: B = QᵀA (p×n), small exact SVD.
    let b = matmul_tn(&q, a);
    let svd_b = svd_thin(&b);
    // U = Q · U_B[:, :r]
    matmul(&q, &svd_b.u.left_cols(r))
}

/// Singular values (descending).
pub fn singular_values(a: &Matrix) -> Vec<f32> {
    svd_thin(a).s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    fn reconstruct(svd: &Svd) -> Matrix {
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..svd.s.len().min(us.cols) {
                us.data[i * us.cols + j] *= svd.s[j];
            }
        }
        matmul(&us, &svd.vt)
    }

    #[test]
    fn reconstructs_wide_and_tall() {
        let mut rng = Pcg::new(0);
        for (m, n) in [(6, 10), (10, 6), (8, 8), (1, 5), (5, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_thin(&a);
            let rec = reconstruct(&svd);
            assert!(
                rec.max_abs_diff(&a) < 1e-3,
                "({m},{n}): err {}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Pcg::new(1);
        let a = Matrix::randn(12, 30, 1.0, &mut rng);
        let svd = svd_thin(&a);
        let utu = matmul_tn(&svd.u, &svd.u);
        assert!(utu.max_abs_diff(&Matrix::eye(12)) < 1e-3);
    }

    #[test]
    fn values_sorted_and_match_norm() {
        let mut rng = Pcg::new(2);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let s = singular_values(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let fro2: f32 = a.data.iter().map(|v| v * v).sum();
        let s2: f32 = s.iter().map(|v| v * v).sum();
        assert!((fro2 - s2).abs() / fro2 < 1e-3);
    }

    #[test]
    fn known_diagonal_case() {
        // diag(3, 2, 1) has singular values 3, 2, 1.
        let mut a = Matrix::zeros(3, 3);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = 2.0;
        *a.at_mut(2, 2) = 1.0;
        let s = singular_values(&a);
        assert!((s[0] - 3.0).abs() < 1e-5);
        assert!((s[1] - 2.0).abs() < 1e-5);
        assert!((s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn top_vectors_capture_low_rank_structure() {
        // A = u vᵀ rank-1: top singular vector must align with u.
        let mut rng = Pcg::new(3);
        let u = Matrix::randn(10, 1, 1.0, &mut rng);
        let v = Matrix::randn(1, 20, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let p = top_singular_vectors(&a, 1);
        // |cos| between p[:,0] and u ≈ 1.
        let dot: f32 = (0..10).map(|i| p.at(i, 0) * u.at(i, 0)).sum();
        let nu: f32 = u.data.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((dot.abs() / nu - 1.0).abs() < 1e-3);
    }

    #[test]
    fn randomized_matches_exact_on_separated_spectrum() {
        use crate::rng::Pcg;
        let mut rng = Pcg::new(5);
        // Rank-heavy matrix: strong top-3 + weak tail.
        let u = Matrix::randn(40, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 80, 1.0, &mut rng);
        let mut a = matmul(&u, &v);
        a.add_scaled_in_place(0.01, &Matrix::randn(40, 80, 1.0, &mut rng));
        let exact = top_singular_vectors(&a, 3);
        let rand = super::top_singular_vectors_randomized(&a, 3, 2, &mut rng);
        // Same subspace: ‖PPᵀ − QQᵀ‖ small ⇔ ‖Pᵀ(I − QQᵀ)‖ small.
        let cross = matmul_tn(&exact, &rand); // 3×3 ≈ orthogonal
        let gram = matmul_tn(&cross, &cross);
        assert!(gram.max_abs_diff(&Matrix::eye(3)) < 1e-2,
                "subspace mismatch: {gram:?}");
        // Orthonormal columns.
        let qtq = matmul_tn(&rand, &rand);
        assert!(qtq.max_abs_diff(&Matrix::eye(3)) < 1e-4);
    }

    #[test]
    fn randomized_handles_rank_clamp() {
        use crate::rng::Pcg;
        let mut rng = Pcg::new(6);
        let a = Matrix::randn(6, 30, 1.0, &mut rng);
        let q = super::top_singular_vectors_randomized(&a, 100, 1, &mut rng);
        assert_eq!(q.shape(), (6, 6));
    }

    #[test]
    fn projector_orthonormal() {
        let mut rng = Pcg::new(4);
        let a = Matrix::randn(16, 40, 1.0, &mut rng);
        let p = top_singular_vectors(&a, 5);
        assert_eq!(p.shape(), (16, 5));
        let ptp = matmul_tn(&p, &p);
        assert!(ptp.max_abs_diff(&Matrix::eye(5)) < 1e-3);
    }
}
