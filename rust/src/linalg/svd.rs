//! Thin SVD via symmetric Jacobi eigendecomposition of the Gram matrix.
//!
//! GaLore's projector needs the top-r *left* singular vectors of the
//! gradient G (m×n). We eigendecompose the smaller Gram side in f64
//! (G·Gᵀ when m ≤ n, else Gᵀ·G), then recover the other factor by one
//! GEMM. Cyclic Jacobi converges quadratically and is embarrassingly
//! stable for the m ≤ ~1k blocks this system handles.

use crate::thread::parallel_chunks;

use super::{matmul, matmul_tn, Matrix};

/// Thin SVD result: `a ≈ u · diag(s) · vt` with `u` m×p, `vt` p×n,
/// p = min(m, n); singular values descending.
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub vt: Matrix,
}

/// Result of [`jacobi_eigh`]: eigenpairs plus the convergence record —
/// a silent fall-through after `max_sweeps` used to be indistinguishable
/// from success, which is exactly the failure mode an ill-conditioned
/// Gram matrix triggers.
pub(crate) struct JacobiEigh {
    /// Eigenvalues, descending.
    pub vals: Vec<f64>,
    /// Eigenvectors as columns of a row-major n×n matrix.
    pub vecs: Vec<f64>,
    /// Sweeps actually executed before the off-diagonal norm passed the
    /// tolerance (or `max_sweeps` if it never did).
    pub sweeps: usize,
    /// False when `max_sweeps` ran out with the off-diagonal norm still
    /// above tolerance.
    pub converged: bool,
}

/// Symmetric eigendecomposition (cyclic Jacobi, f64 accumulation) for a
/// symmetric n×n input given in f64.
pub(crate) fn jacobi_eigh(mut a: Vec<f64>, n: usize) -> JacobiEigh {
    // v = identity
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;

    let max_sweeps = 30;
    let mut sweeps = max_sweeps;
    let mut converged = false;
    for sweep in 0..=max_sweeps {
        // Off-diagonal Frobenius norm for convergence check (also after
        // the final sweep, so the flag reflects the returned state).
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[idx(i, j)] * a[idx(i, j)];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + frob64(&a, n)) {
            sweeps = sweep;
            converged = true;
            break;
        }
        if sweep == max_sweeps {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[idx(p, p)];
                let aqq = a[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for k in 0..n {
                    let akp = a[idx(k, p)];
                    let akq = a[idx(k, q)];
                    a[idx(k, p)] = c * akp - s * akq;
                    a[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[idx(p, k)];
                    let aqk = a[idx(q, k)];
                    a[idx(p, k)] = c * apk - s * aqk;
                    a[idx(q, k)] = s * apk + c * aqk;
                }
                // Accumulate rotations into V (columns are eigenvectors).
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract eigenvalues, sort descending with eigenvectors.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| a[idx(i, i)]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = vec![0.0f64; n * n];
    for (new_j, &old_j) in order.iter().enumerate() {
        for k in 0..n {
            sorted_vecs[idx(k, new_j)] = v[idx(k, old_j)];
        }
    }
    JacobiEigh {
        vals: sorted_vals,
        vecs: sorted_vecs,
        sweeps,
        converged,
    }
}

fn frob64(a: &[f64], n: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n * n {
        s += a[i] * a[i];
    }
    s.sqrt()
}

/// Gram matrix of the smaller side, accumulated in f64.
fn gram_small(a: &Matrix) -> (Vec<f64>, usize, bool) {
    let (m, n) = a.shape();
    let left = m <= n; // gram = A Aᵀ (m×m) if left else Aᵀ A (n×n)
    let p = m.min(n);
    let mut g = vec![0.0f64; p * p];
    if left {
        let out = SendMut(g.as_mut_ptr());
        parallel_chunks(p, 4, |r0, r1| {
            let out = &out;
            for i in r0..r1 {
                let ri = a.row(i);
                for j in i..p {
                    let rj = a.row(j);
                    let mut s = 0.0f64;
                    for k in 0..n {
                        s += ri[k] as f64 * rj[k] as f64;
                    }
                    unsafe {
                        *out.0.add(i * p + j) = s;
                        *out.0.add(j * p + i) = s;
                    }
                }
            }
        });
    } else {
        // Aᵀ A: accumulate over rows (streaming reads of A).
        for k in 0..m {
            let rk = a.row(k);
            for i in 0..p {
                let aki = rk[i] as f64;
                if aki == 0.0 {
                    continue;
                }
                for j in i..p {
                    g[i * p + j] += aki * rk[j] as f64;
                }
            }
        }
        for i in 0..p {
            for j in 0..i {
                g[i * p + j] = g[j * p + i];
            }
        }
    }
    (g, p, left)
}

struct SendMut<T>(*mut T);
unsafe impl<T> Sync for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}

/// Thin SVD. For m ≤ n: eigh(G Gᵀ) → U, then Vᵀ = Σ⁻¹ Uᵀ G.
pub fn svd_thin(a: &Matrix) -> Svd {
    let (m, _n) = a.shape();
    let (g, p, left) = gram_small(a);
    let eigh = jacobi_eigh(g, p);
    debug_assert!(
        eigh.converged,
        "jacobi_eigh: off-diagonal norm above tolerance after {} sweeps \
         ({}x{} Gram)",
        eigh.sweeps,
        p,
        p
    );
    let s: Vec<f32> = eigh
        .vals
        .iter()
        .map(|&v| (v.max(0.0)).sqrt() as f32)
        .collect();

    // Eigenvector matrix (p×p, columns = vectors) as f32 row-major.
    let w = Matrix::from_vec(
        p,
        p,
        eigh.vecs.iter().map(|&v| v as f32).collect(),
    );

    if left {
        // U = W (m×m = p×p), Vᵀ = Σ⁻¹ Uᵀ A (p×n).
        let ut_a = matmul_tn(&w, a);
        let mut vt = ut_a;
        for (i, &si) in s.iter().enumerate() {
            let inv = if si > 1e-12 { 1.0 / si } else { 0.0 };
            for val in vt.row_mut(i) {
                *val *= inv;
            }
        }
        Svd { u: w, s, vt }
    } else {
        // V = W (n×p), U = A V Σ⁻¹ (m×p), Vᵀ = Wᵀ.
        let av = matmul(a, &w);
        let mut u = av;
        for i in 0..m {
            for (j, &sj) in s.iter().enumerate() {
                let inv = if sj > 1e-12 { 1.0 / sj } else { 0.0 };
                u.data[i * p + j] *= inv;
            }
        }
        Svd {
            u,
            s,
            vt: w.transpose(),
        }
    }
}

/// Top-r left singular vectors (GaLore projector P = U[:, :r]), exact.
pub fn top_singular_vectors(a: &Matrix, r: usize) -> Matrix {
    let p = a.rows.min(a.cols).min(r);
    svd_thin(a).u.left_cols(p)
}

/// Singular values (descending).
pub fn singular_values(a: &Matrix) -> Vec<f32> {
    svd_thin(a).s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    fn reconstruct(svd: &Svd) -> Matrix {
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..svd.s.len().min(us.cols) {
                us.data[i * us.cols + j] *= svd.s[j];
            }
        }
        matmul(&us, &svd.vt)
    }

    #[test]
    fn reconstructs_wide_and_tall() {
        let mut rng = Pcg::new(0);
        for (m, n) in [(6, 10), (10, 6), (8, 8), (1, 5), (5, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_thin(&a);
            let rec = reconstruct(&svd);
            assert!(
                rec.max_abs_diff(&a) < 1e-3,
                "({m},{n}): err {}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Pcg::new(1);
        let a = Matrix::randn(12, 30, 1.0, &mut rng);
        let svd = svd_thin(&a);
        let utu = matmul_tn(&svd.u, &svd.u);
        assert!(utu.max_abs_diff(&Matrix::eye(12)) < 1e-3);
    }

    #[test]
    fn values_sorted_and_match_norm() {
        let mut rng = Pcg::new(2);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let s = singular_values(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let fro2: f32 = a.data.iter().map(|v| v * v).sum();
        let s2: f32 = s.iter().map(|v| v * v).sum();
        assert!((fro2 - s2).abs() / fro2 < 1e-3);
    }

    #[test]
    fn known_diagonal_case() {
        // diag(3, 2, 1) has singular values 3, 2, 1.
        let mut a = Matrix::zeros(3, 3);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = 2.0;
        *a.at_mut(2, 2) = 1.0;
        let s = singular_values(&a);
        assert!((s[0] - 3.0).abs() < 1e-5);
        assert!((s[1] - 2.0).abs() < 1e-5);
        assert!((s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn top_vectors_capture_low_rank_structure() {
        // A = u vᵀ rank-1: top singular vector must align with u.
        let mut rng = Pcg::new(3);
        let u = Matrix::randn(10, 1, 1.0, &mut rng);
        let v = Matrix::randn(1, 20, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let p = top_singular_vectors(&a, 1);
        // |cos| between p[:,0] and u ≈ 1.
        let dot: f32 = (0..10).map(|i| p.at(i, 0) * u.at(i, 0)).sum();
        let nu: f32 = u.data.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((dot.abs() / nu - 1.0).abs() < 1e-3);
    }

    /// Regression: `jacobi_eigh` used to fall through `max_sweeps`
    /// silently. On an ill-conditioned input (singular values spanning
    /// ~6 decades, so Gram eigenvalues span ~12) the flag must report
    /// convergence — and the factorization must still be accurate.
    #[test]
    fn jacobi_converges_on_ill_conditioned_gram() {
        let mut rng = Pcg::new(11);
        let n = 24;
        // A = Q₁ · diag(10⁰ … 10⁻⁶) · Q₂ᵀ via two random rotations.
        let q1 = crate::linalg::random_orthonormal(n, n, &mut rng);
        let q2 = crate::linalg::random_orthonormal(n, n, &mut rng);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            *d.at_mut(i, i) = 10f32.powf(-6.0 * i as f32 / (n - 1) as f32);
        }
        let a = matmul(&matmul(&q1, &d), &q2.transpose());
        let (g, p, _) = super::gram_small(&a);
        let eigh = super::jacobi_eigh(g, p);
        assert!(
            eigh.converged,
            "no convergence after {} sweeps",
            eigh.sweeps
        );
        assert!(eigh.sweeps < 30, "sweep budget exhausted");
        // Top singular value recovered through the full pipeline.
        let s = singular_values(&a);
        assert!((s[0] - 1.0).abs() < 1e-3, "σ₁ {}", s[0]);
    }

    #[test]
    fn jacobi_reports_trivial_convergence_on_diagonal_input() {
        // Already diagonal: zero sweeps needed, flag set immediately.
        let n = 6;
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            g[i * n + i] = (n - i) as f64;
        }
        let eigh = super::jacobi_eigh(g, n);
        assert!(eigh.converged);
        assert_eq!(eigh.sweeps, 0);
        assert_eq!(eigh.vals[0], n as f64);
    }

    #[test]
    fn projector_orthonormal() {
        let mut rng = Pcg::new(4);
        let a = Matrix::randn(16, 40, 1.0, &mut rng);
        let p = top_singular_vectors(&a, 5);
        assert_eq!(p.shape(), (16, 5));
        let ptp = matmul_tn(&p, &p);
        assert!(ptp.max_abs_diff(&Matrix::eye(5)) < 1e-3);
    }
}
