//! GEMM shape-class autotuner with a persisted per-host tuning cache.
//!
//! The packed kernel's fixed MC×KC×NC = 128×256×512 tiling (and its
//! hand-picked small-shape cutover) is a compromise across every shape
//! the optimizers produce. The projection work that dominates the
//! paper's mechanism is *not* shape-generic: `PᵀG` is a narrow-M
//! product (r×n output, r ≤ 512), project-back `P·G_lowrank` / `R·Pᵀ`
//! are narrow-K products (k = r), and the rsvd power iterations repeat
//! both. This module classifies each GEMM into a **shape class**, runs
//! a one-time measured search over a small candidate grid of tile
//! sizes and kernel variants for that class, and caches the winner —
//! in memory for the process, and (when a cache path is configured) in
//! a versioned per-host JSON file so later runs skip the search
//! entirely.
//!
//! ## Modes
//!
//! Tuning is **opt-in**. Resolution order: a programmatic override
//! ([`set_mode`], used by the CLI and benches) wins over the `GUM_TUNE`
//! env var (`on`/`off`), which defaults to **off**. Off means the
//! fixed-tiling path in the GEMM driver runs exactly as before — CI
//! and every determinism suite pin this mode, so their trajectories
//! are byte-identical to the pre-tuner tree.
//!
//! ## Determinism contract
//!
//! Tile choice may vary per host (that is the point), but for a
//! *given* choice results are bit-identical across `GUM_THREADS`:
//! every kernel variant preserves the per-element k-summation order
//! (KC slabs ascending, k ascending within a slab) independent of the
//! tile grid, and the variant/tile decision depends only on the shape
//! and the cached table, never on the thread count at call time. The
//! one knob that changes *numerics* (not correctness) is `kc`: a
//! different slab split rounds differently. A warm cache therefore
//! makes whole trajectories reproducible across thread widths; a cold
//! search may pick different winners on different hosts or runs, which
//! is why determinism suites run with tuning off.
//!
//! ## Cache file
//!
//! JSON, written atomically (tmp + fsync + rename, the checkpoint
//! discipline), with a versioned header: `magic`, `version`, `arch`,
//! `isa`, `threads`, then one record per tuned shape class
//! (`class`, `variant`, `mc`/`kc`/`nc`, the shape it was measured on
//! and the measured GFLOP/s). A corrupt, truncated, or
//! wrong-version/wrong-host cache is **silently ignored** — the tuner
//! falls back to searching (or, with tuning off, nothing changes at
//! all). Configure the path with `GUM_TUNE_CACHE` or `--tune-cache`.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::RwLock;
use std::time::Instant;

use crate::util::json::{self, Json};

use super::gemm::{gemm_forced, SMALL_GEMM_FLOPS};
use super::Matrix;

/// Cache file magic string (first header field).
pub const CACHE_MAGIC: &str = "gum-tune-cache";
/// Cache format version; bump when records change shape. v2 replaced
/// the `avx2_fma` bool with the `isa` level label (portable / avx2 /
/// avx512), so v1 caches are silently re-searched.
pub const CACHE_VERSION: u64 = 2;

/// Above the [`SMALL_GEMM_FLOPS`] always-unpacked region and up to this
/// many FLOPs, shapes land in measured `Small` buckets where the search
/// decides unpacked-vs-packed (replacing the single hardcoded cutover).
const SMALL_TUNE_FLOPS: usize = 1 << 22;
/// A dimension at or below this is "narrow" (the projection-rank range).
const NARROW_MAX: usize = 512;
/// The largest dimension must exceed the narrow one by this factor for
/// the shape to count as tall-skinny rather than merely smallish.
const NARROW_RATIO: usize = 4;
/// SharedB packs all of op(B) up front; skip the candidate when the
/// padded panel buffer would exceed this many bytes.
const SHARED_B_MAX_BYTES: usize = 64 << 20;

// ---------------------------------------------------------------------------
// Configurations and shape classes
// ---------------------------------------------------------------------------

/// Kernel variant selected for a shape class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Serial unpacked kernel (no panel packing) — wins when packing
    /// costs more than it saves.
    Unpacked,
    /// The GotoBLAS-style packed path: per-tile op(A)/op(B) packing,
    /// 2-D tile parallelism. Tiles come from the config.
    Blocked,
    /// op(B) packed once up front and shared read-only across row
    /// tiles (1-D row parallelism): for narrow-K/narrow-N shapes the
    /// blocked path repacks the same B panels once per row tile, which
    /// this variant skips. `nc` is unused — B is packed in full.
    SharedB,
}

impl KernelVariant {
    fn as_str(self) -> &'static str {
        match self {
            KernelVariant::Unpacked => "unpacked",
            KernelVariant::Blocked => "blocked",
            KernelVariant::SharedB => "shared-b",
        }
    }

    fn parse(s: &str) -> Option<KernelVariant> {
        match s {
            "unpacked" => Some(KernelVariant::Unpacked),
            "blocked" => Some(KernelVariant::Blocked),
            "shared-b" => Some(KernelVariant::SharedB),
            _ => None,
        }
    }
}

/// One tile configuration: a kernel variant plus its blocking. For
/// `Unpacked` the tile fields are ignored; for `SharedB` only `mc` and
/// `kc` matter (op(B) is packed in full, so there is no `nc` panel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub variant: KernelVariant,
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl TileConfig {
    pub const fn blocked(mc: usize, kc: usize, nc: usize) -> TileConfig {
        TileConfig { variant: KernelVariant::Blocked, mc, kc, nc }
    }

    pub const fn shared_b(mc: usize, kc: usize) -> TileConfig {
        TileConfig { variant: KernelVariant::SharedB, mc, kc, nc: 0 }
    }

    pub const fn unpacked() -> TileConfig {
        TileConfig { variant: KernelVariant::Unpacked, mc: 0, kc: 0, nc: 0 }
    }

    /// Sanity bounds for configs read back from a cache file: a record
    /// outside these is skipped rather than trusted.
    fn is_sane(&self) -> bool {
        match self.variant {
            KernelVariant::Unpacked => true,
            KernelVariant::Blocked => {
                (8..=65536).contains(&self.mc)
                    && (1..=65536).contains(&self.kc)
                    && (8..=65536).contains(&self.nc)
            }
            KernelVariant::SharedB => {
                (8..=65536).contains(&self.mc) && (1..=65536).contains(&self.kc)
            }
        }
    }
}

/// The pinned default: exactly the fixed tiling the kernel shipped
/// with (MC×KC×NC = 128×256×512). Always a search candidate, and the
/// config `GUM_TUNE=off` is equivalent to above the small cutover.
pub fn fixed_config() -> TileConfig {
    TileConfig::blocked(128, 256, 512)
}

/// Shape class: which dimension is narrow (bucketed by magnitude), or
/// a size regime when none is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ShapeClass {
    /// At or below the always-unpacked cutover; never searched.
    Tiny,
    /// Contested small region (2¹⁸..2²²] FLOPs, bucketed by log₂(FLOPs):
    /// the search decides unpacked vs packed per bucket.
    Small(u8),
    /// k ≤ 512 and max-dim ≥ 4k — project-back `P·R` / `R·Pᵀ` shapes.
    NarrowK(u8),
    /// m ≤ 512 and max-dim ≥ 4m — projection `PᵀG` shapes.
    NarrowM(u8),
    /// n ≤ 512 and max-dim ≥ 4n — `G·P` sketch shapes.
    NarrowN(u8),
    /// Everything else (large, roughly square).
    General,
}

/// Cache key: operand orientation plus shape class. Orientation is
/// part of the key because pack cost differs between contiguous and
/// strided reads, so NT and TN can tune to different winners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassKey {
    pub a_trans: bool,
    pub b_trans: bool,
    pub class: ShapeClass,
}

impl ClassKey {
    /// Stable string form used in the cache file, e.g. `nt/k7`.
    pub fn to_cache_string(self) -> String {
        let orient = match (self.a_trans, self.b_trans) {
            (false, false) => "nn",
            (false, true) => "nt",
            (true, false) => "tn",
            (true, true) => "tt",
        };
        let class = match self.class {
            ShapeClass::Tiny => "tiny".to_string(),
            ShapeClass::Small(b) => format!("sm{b}"),
            ShapeClass::NarrowK(b) => format!("k{b}"),
            ShapeClass::NarrowM(b) => format!("m{b}"),
            ShapeClass::NarrowN(b) => format!("n{b}"),
            ShapeClass::General => "gen".to_string(),
        };
        format!("{orient}/{class}")
    }
}

/// log₂ bucket of a narrow dimension, clamped to [3, 9] (8..512).
fn bucket(d: usize) -> u8 {
    let b = (usize::BITS - d.max(1).next_power_of_two().leading_zeros() - 1)
        as u8;
    b.clamp(3, 9)
}

/// Classify one GEMM by orientation and shape. Pure shape → class:
/// no global state, so the mapping is identical on every call site,
/// thread, and host.
pub fn classify(
    a_trans: bool,
    b_trans: bool,
    m: usize,
    n: usize,
    k: usize,
) -> ClassKey {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    let class = if flops <= SMALL_GEMM_FLOPS {
        ShapeClass::Tiny
    } else if flops <= SMALL_TUNE_FLOPS {
        // floor(log2) of a value in (2^18, 2^22]: buckets 18..=22.
        let b = (usize::BITS - flops.leading_zeros()) as u8 - 1;
        ShapeClass::Small(b)
    } else {
        // Narrow dimension: global min, ties broken k > m > n (k first
        // because narrow-k is the dominant projection family).
        let dmax = m.max(n).max(k);
        let (dmin, which) = [(k, 0u8), (m, 1), (n, 2)]
            .into_iter()
            .min_by_key(|&(d, _)| d)
            .unwrap();
        if dmin <= NARROW_MAX && dmax >= NARROW_RATIO * dmin {
            match which {
                0 => ShapeClass::NarrowK(bucket(dmin)),
                1 => ShapeClass::NarrowM(bucket(dmin)),
                _ => ShapeClass::NarrowN(bucket(dmin)),
            }
        } else {
            ShapeClass::General
        }
    };
    ClassKey { a_trans, b_trans, class }
}

/// The candidate grid for one class, built against the first-seen
/// shape. Small on purpose: the search is a handful of timed GEMMs,
/// not an exhaustive sweep. The pinned default is always candidate 0,
/// so ties (and a tuner that finds nothing better) keep today's
/// behavior.
fn candidates(class: ShapeClass, m: usize, n: usize, k: usize) -> Vec<TileConfig> {
    let fixed = fixed_config();
    // Padded op(B) panel-buffer size for the SharedB variant.
    let shared_b_bytes = n.div_ceil(8) * 8 * k * 4;
    let shared_b_ok = shared_b_bytes <= SHARED_B_MAX_BYTES;
    match class {
        ShapeClass::Tiny => vec![TileConfig::unpacked()],
        ShapeClass::Small(_) => vec![
            fixed,
            TileConfig::unpacked(),
            TileConfig::blocked(64, 256, 256),
        ],
        ShapeClass::NarrowK(_) => {
            // k fits one slab: kc = k avoids slab-split overhead.
            let kc = k.min(NARROW_MAX);
            let mut v = vec![
                fixed,
                TileConfig::blocked(128, kc, 512),
                TileConfig::blocked(256, kc, 1024),
            ];
            if shared_b_ok {
                v.push(TileConfig::shared_b(128, kc));
                v.push(TileConfig::shared_b(256, kc));
                v.push(TileConfig::shared_b(512, kc));
            }
            v
        }
        ShapeClass::NarrowM(_) => {
            // One row tile covering all m rows means op(B) is packed
            // exactly once; the grid then explores slab depth and
            // panel width for the big streamed B.
            let mc = m.next_multiple_of(8).min(NARROW_MAX);
            vec![
                fixed,
                TileConfig::blocked(mc, 256, 512),
                TileConfig::blocked(mc, 512, 512),
                TileConfig::blocked(mc, 256, 2048),
                TileConfig::blocked(mc, 512, 2048),
            ]
        }
        ShapeClass::NarrowN(_) => {
            let nc = n.next_multiple_of(8).min(NARROW_MAX);
            let mut v = vec![
                fixed,
                TileConfig::blocked(128, 256, nc),
                TileConfig::blocked(128, 512, nc),
            ];
            if shared_b_ok {
                v.push(TileConfig::shared_b(128, 256));
                v.push(TileConfig::shared_b(128, 512));
            }
            v
        }
        ShapeClass::General => vec![
            fixed,
            TileConfig::blocked(256, 256, 512),
            TileConfig::blocked(128, 512, 512),
            TileConfig::blocked(256, 256, 1024),
        ],
    }
}

// ---------------------------------------------------------------------------
// Global tuner state
// ---------------------------------------------------------------------------

/// Tuning mode: `Off` pins the fixed tiling, `On` enables the measured
/// search + cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneMode {
    Off,
    On,
}

const MODE_UNSET: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_ON: u8 = 2;

/// Resolved mode, cached after the first env read so the per-GEMM
/// check is one atomic load.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Number of measured searches performed by this process (benches and
/// tests use it to prove a warm cache skips the search).
static SEARCHES: AtomicUsize = AtomicUsize::new(0);

struct TuneState {
    /// Programmatic cache-path override (CLI); `None` falls back to
    /// the `GUM_TUNE_CACHE` env var.
    cache_path: Option<PathBuf>,
    /// Whether the cache file has been read (attempted) already.
    loaded: bool,
    /// class-key string → winning config.
    table: BTreeMap<String, TileConfig>,
}

static STATE: RwLock<TuneState> = RwLock::new(TuneState {
    cache_path: None,
    loaded: false,
    table: BTreeMap::new(),
});

fn env_mode() -> TuneMode {
    match std::env::var("GUM_TUNE").ok().as_deref() {
        Some("on") | Some("1") | Some("true") => TuneMode::On,
        _ => TuneMode::Off,
    }
}

fn mode() -> TuneMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_OFF => TuneMode::Off,
        MODE_ON => TuneMode::On,
        _ => {
            let m = env_mode();
            let enc = if m == TuneMode::On { MODE_ON } else { MODE_OFF };
            MODE.store(enc, Ordering::Relaxed);
            m
        }
    }
}

/// Override the tuning mode (CLI / benches / tests). `None` restores
/// env-var resolution. Returns the previous override (`None` when the
/// mode was env-resolved), so callers can save and restore.
pub fn set_mode(m: Option<TuneMode>) -> Option<TuneMode> {
    let prev = match MODE.load(Ordering::Relaxed) {
        MODE_OFF => Some(TuneMode::Off),
        MODE_ON => Some(TuneMode::On),
        _ => None,
    };
    let enc = match m {
        None => MODE_UNSET,
        Some(TuneMode::Off) => MODE_OFF,
        Some(TuneMode::On) => MODE_ON,
    };
    MODE.store(enc, Ordering::Relaxed);
    prev
}

/// Override the cache file path (CLI `--tune-cache`). `None` restores
/// the `GUM_TUNE_CACHE` env fallback. Resets the loaded flag so the
/// next lookup re-reads the (new) file. Returns the previous override.
pub fn set_cache_path(path: Option<PathBuf>) -> Option<PathBuf> {
    let mut st = STATE.write().unwrap();
    st.loaded = false;
    std::mem::replace(&mut st.cache_path, path)
}

/// Drop every in-memory tuning decision and the search counter
/// (tests/benches). The cache file, mode, and path overrides are left
/// alone; the next lookup reloads the file.
pub fn reset() {
    let mut st = STATE.write().unwrap();
    st.table.clear();
    st.loaded = false;
    SEARCHES.store(0, Ordering::Relaxed);
}

/// Measured searches performed by this process so far.
pub fn searches_performed() -> usize {
    SEARCHES.load(Ordering::Relaxed)
}

fn effective_cache_path(st: &TuneState) -> Option<PathBuf> {
    st.cache_path.clone().or_else(|| {
        std::env::var("GUM_TUNE_CACHE").ok().map(PathBuf::from)
    })
}

/// The tuner entry the GEMM driver consults. `None` means "tuning off
/// — run the fixed-tiling path"; `Some(cfg)` is a decision that
/// depends only on the shape class and the cached table.
pub(crate) fn tile_config(
    a_trans: bool,
    b_trans: bool,
    m: usize,
    n: usize,
    k: usize,
) -> Option<TileConfig> {
    if mode() == TuneMode::Off {
        return None;
    }
    let key = classify(a_trans, b_trans, m, n, k);
    if key.class == ShapeClass::Tiny {
        // Same unpacked kernel the fixed path's cutover selects — tiny
        // shapes are never worth a measured search.
        return Some(TileConfig::unpacked());
    }
    let ks = key.to_cache_string();
    {
        let st = STATE.read().unwrap();
        if st.loaded {
            if let Some(cfg) = st.table.get(&ks) {
                return Some(*cfg);
            }
        }
    }
    let mut st = STATE.write().unwrap();
    if !st.loaded {
        st.loaded = true;
        if let Some(path) = effective_cache_path(&st) {
            if let Some(entries) = load_cache_file(&path) {
                // Keep any decisions already made this process — they
                // were measured here and now.
                for (key, cfg) in entries {
                    st.table.entry(key).or_insert(cfg);
                }
            }
        }
        if let Some(cfg) = st.table.get(&ks) {
            return Some(*cfg);
        }
    }
    if let Some(cfg) = st.table.get(&ks) {
        return Some(*cfg);
    }
    let (cfg, gflops, fixed_gflops) = search(key, m, n, k);
    st.table.insert(ks, cfg);
    if let Some(path) = effective_cache_path(&st) {
        // Best-effort persistence: an unwritable cache must never fail
        // a GEMM.
        let _ = save_cache_file(&path, &st.table, (m, n, k, gflops, fixed_gflops));
    }
    Some(cfg)
}

// ---------------------------------------------------------------------------
// Measured search
// ---------------------------------------------------------------------------

/// Deterministic non-denormal fill for measurement operands (values in
/// [-0.5, 0.5); data content doesn't affect f32 GEMM timing, it only
/// needs to be cheap and denormal-free).
fn pattern_matrix(rows: usize, cols: usize, salt: u32) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for (i, v) in m.data.iter_mut().enumerate() {
        let h = (i as u32)
            .wrapping_mul(2_654_435_761)
            .wrapping_add(salt);
        *v = ((h >> 16) & 0xff) as f32 / 255.0 - 0.5;
    }
    m
}

/// Time one candidate: a warmup call, then adaptively few timed reps
/// (cheap shapes get more reps, expensive ones fewer), scored by the
/// minimum.
fn time_config(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    key: ClassKey,
    cfg: TileConfig,
) -> f64 {
    let run = |c: &mut Matrix| {
        gemm_forced(1.0, a, b, 0.0, c, key.a_trans, key.b_trans, cfg);
    };
    run(c); // warmup: page in scratch, settle the pool
    let t0 = Instant::now();
    run(c);
    let first = t0.elapsed().as_secs_f64();
    let extra_reps = if first < 1e-3 {
        6
    } else if first < 1e-2 {
        3
    } else if first < 5e-2 {
        1
    } else {
        0
    };
    let mut best = first;
    for _ in 0..extra_reps {
        let t = Instant::now();
        run(c);
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Run the measured search for one class on its first-seen shape.
/// Returns the winner plus (winner, fixed-default) GFLOP/s for the
/// cache record.
fn search(key: ClassKey, m: usize, n: usize, k: usize) -> (TileConfig, f64, f64) {
    SEARCHES.fetch_add(1, Ordering::Relaxed);
    let cands = candidates(key.class, m, n, k);
    let (ar, ac) = if key.a_trans { (k, m) } else { (m, k) };
    let (br, bc) = if key.b_trans { (n, k) } else { (k, n) };
    let a = pattern_matrix(ar, ac, 0x9e37_79b9);
    let b = pattern_matrix(br, bc, 0x85eb_ca6b);
    let mut c = Matrix::zeros(m, n);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let mut best = cands[0];
    let mut best_t = f64::INFINITY;
    let mut fixed_t = f64::INFINITY;
    for &cand in &cands {
        let t = time_config(&a, &b, &mut c, key, cand);
        if cand == fixed_config() {
            fixed_t = t;
        }
        // Strict less-than: ties keep the earlier candidate, and the
        // pinned default is always first in its grid position.
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    (best, flops / 1e9 / best_t, flops / 1e9 / fixed_t)
}

// ---------------------------------------------------------------------------
// Cache persistence
// ---------------------------------------------------------------------------

fn host_fingerprint() -> (String, &'static str) {
    // The *probed* level (hardware ∩ env overrides), not any runtime
    // test cap: tuned tiles measured on one ISA path must not be
    // reused on another (different microkernel widths), and the env
    // overrides pin the path for the whole process.
    (
        std::env::consts::ARCH.to_string(),
        super::isa::probed().label(),
    )
}

fn config_to_json(key: &str, cfg: &TileConfig) -> Json {
    Json::obj(vec![
        ("class", Json::str(key)),
        ("variant", Json::str(cfg.variant.as_str())),
        ("mc", Json::num(cfg.mc as f64)),
        ("kc", Json::num(cfg.kc as f64)),
        ("nc", Json::num(cfg.nc as f64)),
    ])
}

/// Parse one cache record; `None` skips the record (unknown variant,
/// insane tiles) without poisoning the rest of the file.
fn config_from_json(j: &Json) -> Option<(String, TileConfig)> {
    let key = j.get("class")?.as_str()?.to_string();
    let variant = KernelVariant::parse(j.get("variant")?.as_str()?)?;
    let cfg = TileConfig {
        variant,
        mc: j.get("mc")?.as_usize()?,
        kc: j.get("kc")?.as_usize()?,
        nc: j.get("nc")?.as_usize()?,
    };
    if cfg.is_sane() {
        Some((key, cfg))
    } else {
        None
    }
}

/// Read a cache file. Any failure — missing file, unparseable JSON,
/// wrong magic/version, different host fingerprint — returns `None`
/// and the caller proceeds as if no cache existed (the silent-fallback
/// contract; a stale cache must never break a run).
pub fn load_cache_file(path: &std::path::Path) -> Option<BTreeMap<String, TileConfig>> {
    let text = std::fs::read_to_string(path).ok()?;
    let doc = json::parse(&text).ok()?;
    if doc.get("magic")?.as_str()? != CACHE_MAGIC {
        return None;
    }
    if doc.get("version")?.as_f64()? as u64 != CACHE_VERSION {
        return None;
    }
    let (arch, isa) = host_fingerprint();
    if doc.get("arch")?.as_str()? != arch {
        return None;
    }
    if doc.get("isa")?.as_str()? != isa {
        return None;
    }
    let mut table = BTreeMap::new();
    for entry in doc.get("entries")?.as_arr()? {
        if let Some((key, cfg)) = config_from_json(entry) {
            table.insert(key, cfg);
        }
    }
    Some(table)
}

/// Write the full table atomically (tmp + fsync + rename — the
/// checkpoint discipline, so a crash mid-write can't leave a torn
/// cache for the next run's silent-fallback path to reject).
/// `last_measured` annotates the file with the most recent search's
/// shape and GFLOP/s — informational only, ignored on load.
fn save_cache_file(
    path: &std::path::Path,
    table: &BTreeMap<String, TileConfig>,
    last_measured: (usize, usize, usize, f64, f64),
) -> std::io::Result<()> {
    use std::io::Write;

    let (arch, isa) = host_fingerprint();
    let entries: Vec<Json> =
        table.iter().map(|(k, c)| config_to_json(k, c)).collect();
    let (m, n, k, gflops, fixed_gflops) = last_measured;
    let doc = Json::obj(vec![
        ("magic", Json::str(CACHE_MAGIC)),
        ("version", Json::num(CACHE_VERSION as f64)),
        ("arch", Json::str(arch)),
        ("isa", Json::str(isa)),
        ("threads", Json::num(crate::thread::num_threads() as f64)),
        ("entries", Json::arr(entries)),
        (
            "last_measured",
            Json::obj(vec![
                ("m", Json::num(m as f64)),
                ("n", Json::num(n as f64)),
                ("k", Json::num(k as f64)),
                ("tuned_gflops", Json::num(gflops)),
                ("fixed_gflops", Json::num(fixed_gflops)),
            ]),
        ),
    ]);

    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).ok();
        }
    }
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "tune cache path has no file name",
            )
        })?;
    let tmp = path.with_file_name(format!("{file_name}.tmp"));
    let write_result: std::io::Result<()> = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(doc.to_string_pretty().as_bytes())?;
        f.sync_all()
    })();
    if let Err(err) = write_result {
        let _ = std::fs::remove_file(&tmp);
        return Err(err);
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_projection_shapes() {
        // Project-back P·R (NN) and R·Pᵀ (NT): narrow-k.
        assert_eq!(
            classify(false, false, 1024, 4096, 32).class,
            ShapeClass::NarrowK(5)
        );
        assert_eq!(
            classify(false, true, 1024, 4096, 512).class,
            ShapeClass::NarrowK(9)
        );
        // Projection PᵀG (TN): narrow-m (output rows = r).
        assert_eq!(
            classify(true, false, 128, 4096, 1024).class,
            ShapeClass::NarrowM(7)
        );
        // Sketch G·P: narrow-n.
        assert_eq!(
            classify(false, false, 1024, 64, 4096).class,
            ShapeClass::NarrowN(6)
        );
        // Large square: general.
        assert_eq!(
            classify(false, false, 1024, 1024, 1024).class,
            ShapeClass::General
        );
        // At/below the cutover: tiny (64·64·32·2 = 2^18).
        assert_eq!(
            classify(false, false, 64, 64, 32).class,
            ShapeClass::Tiny
        );
        // Contested small region: 64³·2 = 2^19.
        assert_eq!(
            classify(false, false, 64, 64, 64).class,
            ShapeClass::Small(19)
        );
    }

    #[test]
    fn buckets_clamp_and_ascend() {
        assert_eq!(bucket(1), 3);
        assert_eq!(bucket(8), 3);
        assert_eq!(bucket(32), 5);
        assert_eq!(bucket(128), 7);
        assert_eq!(bucket(512), 9);
        assert_eq!(bucket(4096), 9);
    }

    #[test]
    fn candidate_grids_are_sane_and_start_fixed() {
        for class in [
            ShapeClass::Small(20),
            ShapeClass::NarrowK(7),
            ShapeClass::NarrowM(7),
            ShapeClass::NarrowN(7),
            ShapeClass::General,
        ] {
            let cands = candidates(class, 1024, 4096, 128);
            assert_eq!(cands[0], fixed_config(), "{class:?}");
            assert!(cands.len() >= 3, "{class:?}");
            for c in &cands {
                assert!(c.is_sane(), "{class:?} {c:?}");
            }
        }
    }

    #[test]
    fn class_key_strings_are_stable() {
        let key = classify(false, true, 1024, 4096, 128);
        assert_eq!(key.to_cache_string(), "nt/k7");
        let key = classify(true, false, 128, 4096, 1024);
        assert_eq!(key.to_cache_string(), "tn/m7");
        let key = classify(false, false, 1024, 1024, 1024);
        assert_eq!(key.to_cache_string(), "nn/gen");
    }

    #[test]
    fn cache_rejects_wrong_header_silently() {
        let dir = std::env::temp_dir().join("gum_tune_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_header.json");
        // Wrong magic.
        std::fs::write(&path, r#"{"magic": "nope", "version": 1}"#).unwrap();
        assert!(load_cache_file(&path).is_none());
        // Truncated / invalid JSON.
        std::fs::write(&path, r#"{"magic": "gum-tune-cac"#).unwrap();
        assert!(load_cache_file(&path).is_none());
        // Missing file.
        assert!(load_cache_file(&dir.join("absent.json")).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("gum_tune_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        let mut table = BTreeMap::new();
        table.insert("nt/k7".to_string(), TileConfig::shared_b(256, 128));
        table.insert("tn/m7".to_string(), TileConfig::blocked(128, 512, 2048));
        table.insert("nn/gen".to_string(), fixed_config());
        save_cache_file(&path, &table, (1024, 4096, 128, 40.0, 33.0)).unwrap();
        let loaded = load_cache_file(&path).expect("valid cache loads");
        assert_eq!(loaded, table);
        // Insane records are skipped, sane siblings kept.
        let text = std::fs::read_to_string(&path).unwrap();
        let doctored = text.replace("\"mc\": 256", "\"mc\": 0");
        std::fs::write(&path, doctored).unwrap();
        let loaded = load_cache_file(&path).expect("header still valid");
        assert!(!loaded.contains_key("nt/k7"));
        assert!(loaded.contains_key("tn/m7"));
        let _ = std::fs::remove_file(&path);
    }
}
