//! Matrix norms & spectra: Frobenius, trace (nuclear), spectral estimate,
//! and the stable rank ‖M‖_F²/‖M‖₂² central to the paper's Figure 2.

use crate::rng::Pcg;

use super::{singular_values, Matrix};

/// Frobenius norm.
pub fn fro_norm(a: &Matrix) -> f32 {
    let s: f64 = a.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
    s.sqrt() as f32
}

/// Spectral norm (largest singular value) via power iteration on AᵀA.
pub fn spectral_norm_est(a: &Matrix, iters: usize) -> f32 {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return 0.0;
    }
    let mut rng = Pcg::new(0x5eed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut sigma = 0.0f64;
    for _ in 0..iters {
        // w = A v (m), u = Aᵀ w (n)
        let mut w = vec![0.0f64; m];
        for i in 0..m {
            let row = a.row(i);
            let mut s = 0.0f64;
            for j in 0..n {
                s += row[j] as f64 * v[j];
            }
            w[i] = s;
        }
        let mut u = vec![0.0f64; n];
        for i in 0..m {
            let row = a.row(i);
            let wi = w[i];
            for j in 0..n {
                u[j] += row[j] as f64 * wi;
            }
        }
        sigma = norm(&u).sqrt();
        v = u;
        normalize(&mut v);
    }
    sigma as f32
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

/// Trace (nuclear) norm: sum of singular values (exact, via SVD).
pub fn trace_norm(a: &Matrix) -> f32 {
    singular_values(a).iter().sum()
}

/// Stable rank ‖M‖_F² / ‖M‖₂² (paper Fig. 2). Uses power iteration for
/// the spectral norm; exact enough after 30 iterations for the scales
/// here.
pub fn stable_rank(a: &Matrix) -> f32 {
    let f = fro_norm(a);
    let s = spectral_norm_est(a, 30);
    if s <= 0.0 {
        return 0.0;
    }
    (f * f) / (s * s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;

    #[test]
    fn fro_basic() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((fro_norm(&a) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn spectral_matches_svd() {
        let mut rng = Pcg::new(0);
        let a = Matrix::randn(10, 16, 1.0, &mut rng);
        let est = spectral_norm_est(&a, 50);
        let exact = singular_values(&a)[0];
        assert!((est - exact).abs() / exact < 1e-3, "{est} vs {exact}");
    }

    #[test]
    fn trace_norm_of_orthogonal_is_rank() {
        let mut rng = Pcg::new(1);
        let q = crate::linalg::random_orthonormal(12, 5, &mut rng);
        // Q has 5 unit singular values → trace norm 5.
        assert!((trace_norm(&q) - 5.0).abs() < 1e-3);
    }

    #[test]
    fn stable_rank_bounds() {
        let mut rng = Pcg::new(2);
        // Rank-1: stable rank ≈ 1.
        let u = Matrix::randn(8, 1, 1.0, &mut rng);
        let v = Matrix::randn(1, 12, 1.0, &mut rng);
        let r1 = matmul(&u, &v);
        assert!((stable_rank(&r1) - 1.0).abs() < 1e-2);
        // Identity: stable rank = n.
        let id = Matrix::eye(7);
        assert!((stable_rank(&id) - 7.0).abs() < 1e-2);
    }
}
