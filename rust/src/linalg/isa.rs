//! Cached CPU ISA probe shared by the SIMD engines (gemm, elementwise,
//! lowp).
//!
//! One process-wide probe resolves the widest usable instruction-set
//! level once (relaxed atomics — the probe is idempotent, so a benign
//! race at worst repeats the cpuid check). Two override env vars are
//! read at that first probe:
//!
//! * `GUM_FORCE_PORTABLE` — non-empty and not `"0"` forces the
//!   portable scalar path everywhere (CI runs the kernel suites under
//!   it so the fallback stays exercised).
//! * `GUM_FORCE_AVX2` — caps the level at AVX2 even when AVX-512 is
//!   available (cross-path comparison runs).
//!
//! Tests that need to flip paths *within* a process use [`force_cap`]
//! (or the [`force_portable`] convenience wrapper), which clamps the
//! effective level without touching the cached hardware probe.
//!
//! # Determinism contract
//!
//! Within one resolved level, every kernel in the crate is bit-exact
//! across `GUM_THREADS`, replica splits, and chunk boundaries: threads
//! only partition index ranges, and each output element is a pure
//! function of its own index. *Across* levels results may differ in
//! the last ulp (FMA contraction on the AVX2/AVX-512 paths vs separate
//! multiply-add on the portable path), which is why the level is
//! resolved once per process and recorded in the tune-cache host
//! fingerprint.

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set level the dispatchers select between. Ordered:
/// a cap at level L means "at most L".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    /// Scalar bodies, no `target_feature` — the reference path.
    Portable = 0,
    /// AVX2 + FMA (8 f32 lanes).
    Avx2 = 1,
    /// AVX-512F + AVX-512BW (16 f32 lanes; BW covers the 16-bit
    /// shuffles the lowp converters autovectorize into).
    Avx512 = 2,
}

impl IsaLevel {
    /// Stable label used in the tune-cache host fingerprint and logs.
    pub fn label(self) -> &'static str {
        match self {
            IsaLevel::Portable => "portable",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Avx512 => "avx512",
        }
    }

    fn from_u8(v: u8) -> IsaLevel {
        match v {
            0 => IsaLevel::Portable,
            1 => IsaLevel::Avx2,
            _ => IsaLevel::Avx512,
        }
    }
}

/// 0 = unprobed; otherwise `level as u8 + 1`.
static PROBE: AtomicU8 = AtomicU8::new(0);
/// Runtime clamp for in-process cross-path tests; `CAP_NONE` = no cap.
static CAP: AtomicU8 = AtomicU8::new(CAP_NONE);
const CAP_NONE: u8 = u8::MAX;

fn env_truthy(name: &str) -> bool {
    std::env::var(name).map_or(false, |v| !v.is_empty() && v != "0")
}

#[cfg(target_arch = "x86_64")]
fn hw_level() -> IsaLevel {
    if std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512bw")
    {
        IsaLevel::Avx512
    } else if std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
    {
        IsaLevel::Avx2
    } else {
        IsaLevel::Portable
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn hw_level() -> IsaLevel {
    IsaLevel::Portable
}

fn detect() -> IsaLevel {
    if env_truthy("GUM_FORCE_PORTABLE") {
        return IsaLevel::Portable;
    }
    let hw = hw_level();
    if env_truthy("GUM_FORCE_AVX2") {
        hw.min(IsaLevel::Avx2)
    } else {
        hw
    }
}

/// The cached probe result (hardware ∩ env overrides), ignoring any
/// runtime cap. This is what the tune-cache fingerprint records.
pub fn probed() -> IsaLevel {
    match PROBE.load(Ordering::Relaxed) {
        0 => {
            let lvl = detect();
            PROBE.store(lvl as u8 + 1, Ordering::Relaxed);
            lvl
        }
        v => IsaLevel::from_u8(v - 1),
    }
}

/// The effective dispatch level: the cached probe clamped by any
/// runtime cap installed via [`force_cap`] / [`force_portable`].
pub fn level() -> IsaLevel {
    let p = probed();
    match CAP.load(Ordering::Relaxed) {
        CAP_NONE => p,
        c => p.min(IsaLevel::from_u8(c)),
    }
}

/// Install (or clear, with `None`) a runtime cap on the dispatch level
/// and return the previous cap. Test-only in spirit: serialize callers
/// with a lock, and restore the previous cap when done.
pub fn force_cap(cap: Option<IsaLevel>) -> Option<IsaLevel> {
    let raw = cap.map_or(CAP_NONE, |l| l as u8);
    match CAP.swap(raw, Ordering::SeqCst) {
        CAP_NONE => None,
        c => Some(IsaLevel::from_u8(c)),
    }
}

/// Convenience wrapper for the common cross-path test: cap at portable
/// (`true`) or clear the cap (`false`). Returns whether the portable
/// cap was previously installed, so callers can save/restore.
pub fn force_portable(on: bool) -> bool {
    let prev = force_cap(if on { Some(IsaLevel::Portable) } else { None });
    prev == Some(IsaLevel::Portable)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(IsaLevel::Portable < IsaLevel::Avx2);
        assert!(IsaLevel::Avx2 < IsaLevel::Avx512);
        assert_eq!(IsaLevel::Avx512.min(IsaLevel::Avx2), IsaLevel::Avx2);
    }

    // Note: no unit test flips the runtime cap here — the lib test
    // binary runs modules concurrently and the gemm/elementwise
    // bitwise-identity tests must not observe a mid-run path switch.
    // Cap save/restore is exercised by the serialized integration
    // suites (tests/elementwise_kernels.rs, tests/state_dtype.rs).

    #[test]
    fn labels_are_stable() {
        assert_eq!(IsaLevel::Portable.label(), "portable");
        assert_eq!(IsaLevel::Avx2.label(), "avx2");
        assert_eq!(IsaLevel::Avx512.label(), "avx512");
    }
}
