//! Blocked, threaded GEMM — the L3 hot path's FLOP sink.
//!
//! `C = alpha * op(A) · op(B) + beta * C` with row-major matrices.
//! Strategy: parallelize over row panels of C, inner kernel is an
//! i–k–j loop with a unrolled j-axis so the compiler auto-vectorizes the
//! `C[i, :] += a_ik * B[k, :]` row updates (streaming, no transposition
//! needed for the NN case). TN/NT variants materialize nothing.

use crate::thread::parallel_chunks;

use super::Matrix;

/// Minimum rows per thread chunk before threading kicks in.
const PAR_MIN_ROWS: usize = 16;

/// C = alpha·A·B + beta·C (shapes: A m×k, B k×n, C m×n).
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dim: {:?}x{:?}", a.shape(), b.shape());
    assert_eq!(c.rows, a.rows, "gemm out rows");
    assert_eq!(c.cols, b.cols, "gemm out cols");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendMut(c.data.as_mut_ptr());

    parallel_chunks(m, PAR_MIN_ROWS, |r0, r1| {
        let c_ptr = &c_ptr;
        // Prescale / clear the C panel.
        for i in r0..r1 {
            // SAFETY: disjoint row ranges per chunk.
            let c_row = unsafe {
                std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n)
            };
            if beta == 0.0 {
                c_row.fill(0.0);
            } else if beta != 1.0 {
                for v in c_row.iter_mut() {
                    *v *= beta;
                }
            }
        }
        // 4-row micro-kernel: each B row is loaded once per 4 C rows,
        // quadrupling FMA per byte of B traffic (§Perf).
        let mut i = r0;
        while i + 4 <= r1 {
            let c = unsafe {
                std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), 4 * n)
            };
            let (c0, rest) = c.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            let a0 = &a_data[i * k..(i + 1) * k];
            let a1 = &a_data[(i + 1) * k..(i + 2) * k];
            let a2 = &a_data[(i + 2) * k..(i + 3) * k];
            let a3 = &a_data[(i + 3) * k..(i + 4) * k];
            for kk in 0..k {
                let b_row = &b_data[kk * n..(kk + 1) * n];
                axpy4(
                    alpha * a0[kk],
                    alpha * a1[kk],
                    alpha * a2[kk],
                    alpha * a3[kk],
                    b_row,
                    c0,
                    c1,
                    c2,
                    c3,
                );
            }
            i += 4;
        }
        for i in i..r1 {
            let c_row = unsafe {
                std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n)
            };
            let a_row = &a_data[i * k..(i + 1) * k];
            for (kk, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                axpy(alpha * aik, &b_data[kk * n..(kk + 1) * n], c_row);
            }
        }
    });
}

/// Four simultaneous row updates: cᵣ += sᵣ·b. `chunks_exact` gives the
/// auto-vectorizer bounds-check-free bodies.
#[allow(clippy::too_many_arguments)]
#[inline]
fn axpy4(
    s0: f32,
    s1: f32,
    s2: f32,
    s3: f32,
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
) {
    let n = b.len();
    let lanes = n / 16 * 16;
    let (bh, bt) = b.split_at(lanes);
    macro_rules! row {
        ($c:ident, $s:ident) => {
            if $s != 0.0 {
                let (ch, ct) = $c.split_at_mut(lanes);
                for (cc, bb) in
                    ch.chunks_exact_mut(16).zip(bh.chunks_exact(16))
                {
                    for l in 0..16 {
                        cc[l] += $s * bb[l];
                    }
                }
                for (cc, bb) in ct.iter_mut().zip(bt) {
                    *cc += $s * bb;
                }
            }
        };
    }
    row!(c0, s0);
    row!(c1, s1);
    row!(c2, s2);
    row!(c3, s3);
}

/// c += s * b (bounds-check-free via chunks_exact).
#[inline]
fn axpy(s: f32, b: &[f32], c: &mut [f32]) {
    let n = c.len();
    let lanes = n / 16 * 16;
    let (bh, bt) = b.split_at(lanes);
    let (ch, ct) = c.split_at_mut(lanes);
    for (cc, bb) in ch.chunks_exact_mut(16).zip(bh.chunks_exact(16)) {
        for l in 0..16 {
            cc[l] += s * bb[l];
        }
    }
    for (cc, bb) in ct.iter_mut().zip(bt) {
        *cc += s * bb;
    }
}

struct SendMut<T>(*mut T);
unsafe impl<T> Sync for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}

/// C = A · B. Routed through the dot-product kernel against Bᵀ — on
/// this hardware the contiguous-dot kernel sustains ~5× the GFLOP/s of
/// the row-update (axpy) kernel, and the O(k·n) transpose amortizes over
/// m output rows (§Perf).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul dims {:?}x{:?}", a.shape(), b.shape());
    let bt = b.transpose();
    matmul_nt(a, &bt)
}

/// C = Aᵀ · B (projection PᵀG): both operands transposed into the
/// dot-kernel layout.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dims");
    let at = a.transpose();
    let bt = b.transpose();
    matmul_nt(&at, &bt)
}

/// C = A · Bᵀ — the core kernel: blocked dot products (4 B-rows per
/// A-row pass for register-level reuse of the streamed A row).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt dims");
    let (m, n, k) = (a.rows, b.rows, a.cols);
    let mut c = Matrix::zeros(m, n);
    let a_data = &a.data;
    let b_data = &b.data;
    let c_ptr = SendMut(c.data.as_mut_ptr());
    parallel_chunks(m, PAR_MIN_ROWS, |r0, r1| {
        let c_ptr = &c_ptr;
        for i in r0..r1 {
            let c_row = unsafe {
                std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n)
            };
            let a_row = &a_data[i * k..(i + 1) * k];
            let mut j = 0;
            while j + 4 <= n {
                let (d0, d1, d2, d3) = dot4(
                    a_row,
                    &b_data[j * k..(j + 1) * k],
                    &b_data[(j + 1) * k..(j + 2) * k],
                    &b_data[(j + 2) * k..(j + 3) * k],
                    &b_data[(j + 3) * k..(j + 4) * k],
                );
                c_row[j] = d0;
                c_row[j + 1] = d1;
                c_row[j + 2] = d2;
                c_row[j + 3] = d3;
                j += 4;
            }
            for j in j..n {
                c_row[j] = dot(a_row, &b_data[j * k..(j + 1) * k]);
            }
        }
    });
    c
}

/// Four simultaneous dot products sharing one streamed `a` row.
#[inline]
fn dot4(
    a: &[f32],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) -> (f32, f32, f32, f32) {
    let n = a.len();
    let lanes = n / 16 * 16;
    let mut acc0 = [0.0f32; 16];
    let mut acc1 = [0.0f32; 16];
    let mut acc2 = [0.0f32; 16];
    let mut acc3 = [0.0f32; 16];
    let (ah, at) = a.split_at(lanes);
    let (b0h, b0t) = b0.split_at(lanes);
    let (b1h, b1t) = b1.split_at(lanes);
    let (b2h, b2t) = b2.split_at(lanes);
    let (b3h, b3t) = b3.split_at(lanes);
    for ((((aa, x0), x1), x2), x3) in ah
        .chunks_exact(16)
        .zip(b0h.chunks_exact(16))
        .zip(b1h.chunks_exact(16))
        .zip(b2h.chunks_exact(16))
        .zip(b3h.chunks_exact(16))
    {
        for l in 0..16 {
            acc0[l] += aa[l] * x0[l];
            acc1[l] += aa[l] * x1[l];
            acc2[l] += aa[l] * x2[l];
            acc3[l] += aa[l] * x3[l];
        }
    }
    let mut s0: f32 = acc0.iter().sum();
    let mut s1: f32 = acc1.iter().sum();
    let mut s2: f32 = acc2.iter().sum();
    let mut s3: f32 = acc3.iter().sum();
    for (i, &x) in at.iter().enumerate() {
        s0 += x * b0t[i];
        s1 += x * b1t[i];
        s2 += x * b2t[i];
        s3 += x * b3t[i];
    }
    (s0, s1, s2, s3)
}

/// Accumulating dot product, 16-lane accumulators for auto-vectorization.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let lanes = n / 16 * 16;
    let mut acc = [0.0f32; 16];
    let (ah, at) = a.split_at(lanes);
    let (bh, bt) = b.split_at(lanes);
    for (aa, bb) in ah.chunks_exact(16).zip(bh.chunks_exact(16)) {
        for l in 0..16 {
            acc[l] += aa[l] * bb[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg::new(0);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 64, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Pcg::new(1);
        let a = Matrix::randn(8, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 10, 1.0, &mut rng);
        let c0 = Matrix::randn(8, 10, 1.0, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c);
        let mut want = naive(&a, &b);
        want.scale_in_place(2.0);
        want.add_scaled_in_place(0.5, &c0);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg::new(2);
        let a = Matrix::randn(23, 11, 1.0, &mut rng);
        let b = Matrix::randn(23, 17, 1.0, &mut rng);
        let tn = matmul_tn(&a, &b);
        let want = matmul(&a.transpose(), &b);
        assert!(tn.max_abs_diff(&want) < 1e-4);

        let c = Matrix::randn(9, 23, 1.0, &mut rng);
        let d = Matrix::randn(31, 23, 1.0, &mut rng);
        let nt = matmul_nt(&c, &d);
        let want = matmul(&c, &d.transpose());
        assert!(nt.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn dot_basic() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..19).map(|i| (i * 2) as f32).collect();
        let want: f32 = (0..19).map(|i| (i * i * 2) as f32).sum();
        assert_eq!(dot(&a, &b), want);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg::new(3);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        let i = Matrix::eye(12);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }
}
