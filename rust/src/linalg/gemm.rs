//! Packed, cache-blocked, threaded GEMM — the L3 hot path's FLOP sink.
//!
//! `C = alpha * op(A) · op(B) + beta * C` with row-major matrices and
//! `op ∈ {identity, transpose}` handled by the *packing* step, so the
//! NN/NT/TN paths share one register microkernel and nothing ever
//! materializes a transposed copy (the pre-packing kernel allocated a
//! full `transpose()` per `matmul`/`matmul_tn` call).
//!
//! Blocking scheme (GotoBLAS/BLIS layering):
//!
//! ```text
//! for (ic, jc) C tiles of mc×nc       — 2-D split over the thread pool
//!   prescale C tile by beta
//!   for pc in (0..k).step_by(KC)      — serial: fixed f32 sum order
//!     pack op(B)[pc.., jc..]  → Bp    (KC×nc, NR-column panels)
//!     pack op(A)[ic.., pc..]  → Ap    (mc×KC, MR-row panels)
//!     for each NR-col panel × MR-row panel:
//!       acc[MR×NR] = Ap-panel · Bp-panel   (register microkernel)
//!       C tile += alpha · acc
//! ```
//!
//! Panels are packed into thread-local scratch (zero-padded to the
//! MR/NR grid), so the microkernel body is branch- and bounds-check-
//! free and the same for interior and edge tiles. On x86-64 the
//! microkernel dispatches once (cached, via [`super::isa`]) to an
//! AVX-512F/BW specialization (16-wide B panels) or an AVX2+FMA one
//! (8-wide) when the CPU supports them; the generic body is the
//! fallback and the only path on other architectures.
//!
//! Determinism contract: every C element is owned by exactly one tile,
//! and its k-axis summation order (KC slabs ascending, k ascending
//! within a slab) is independent of the tile grid, of the panel width
//! NR, and of `GUM_THREADS`, so results are bit-identical under any
//! thread count *within a fixed ISA path* (asserted by
//! `rust/tests/gemm_kernels.rs`; `GUM_FORCE_PORTABLE` /
//! `GUM_FORCE_AVX2` pin the path for cross-path comparisons — see
//! `linalg::isa`).
//!
//! Tiling is resolved per call: with tuning off (the default) the
//! fixed MC×KC×NC blocking and the small-shape cutover below run
//! unchanged; with `GUM_TUNE=on` the [`super::tune`] autotuner hands
//! back a measured [`TileConfig`] per shape class — same kernels, same
//! per-element summation order for a given `kc`, so any single choice
//! is still bit-identical across thread counts. [`gemm_forced`]
//! bypasses the tuner and runs an explicit config (the tuner's own
//! measurement probe, and the bench/test hook).

use std::cell::RefCell;

use crate::thread::{num_threads, parallel_chunks};

use super::tune::{self, KernelVariant, TileConfig};
use super::Matrix;

/// Microkernel tile: MR rows × NR columns of C held in registers. NR
/// is the *base* panel width (portable and AVX2 paths); the AVX-512
/// microkernel widens its B panels to [`NR_MAX`], and the runtime
/// width rides alongside the kernel pointer through the packing and
/// tile loops. The accumulator tile is always sized for `NR_MAX` so
/// the fn-pointer type is width-independent.
const MR: usize = 8;
const NR: usize = 8;
const NR_MAX: usize = 16;
/// Cache blocking: A panels are MC×KC (L2-resident), B panels KC×NC.
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 512;
/// Minimum FLOPs per thread chunk before parallel dispatch pays off.
const PAR_MIN_FLOPS: usize = 1 << 18;
/// At or below this many FLOPs (2·m·n·k) the panel packing costs more
/// than it saves and the unpacked [`small_gemm`] kernel runs instead:
/// `BENCH_gemm.json` shows the packed path losing to the legacy kernel
/// on the 64² r32 smoke shapes (e.g. `smoke_nt_64x64_r32`, 2¹⁸ FLOPs)
/// while winning ≥1.9× from 256² r32 (2²² FLOPs) up. Dispatch depends
/// only on the shape, so results stay bit-identical across
/// `GUM_THREADS`. The autotuner's `Tiny` class reuses this bound.
pub(crate) const SMALL_GEMM_FLOPS: usize = 1 << 18;

/// A borrowed operand under an optional transpose: the *logical*
/// matrix is `X` (trans = false) or `Xᵀ` (trans = true); `ld` is the
/// leading dimension of the stored row-major buffer.
#[derive(Clone, Copy)]
struct OpView<'a> {
    data: &'a [f32],
    ld: usize,
    trans: bool,
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// C = alpha·A·B + beta·C (shapes: A m×k, B k×n, C m×n).
pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dim: {:?}x{:?}", a.shape(), b.shape());
    assert_eq!(c.rows, a.rows, "gemm out rows");
    assert_eq!(c.cols, b.cols, "gemm out cols");
    gemm_driver(
        alpha,
        OpView { data: &a.data, ld: a.cols, trans: false },
        OpView { data: &b.data, ld: b.cols, trans: false },
        beta,
        a.rows,
        b.cols,
        a.cols,
        c,
    );
}

/// C = alpha·A·Bᵀ + beta·C (shapes: A m×k, B n×k, C m×n).
pub fn gemm_nt(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "gemm_nt inner dim: {:?}x{:?}", a.shape(), b.shape());
    assert_eq!(c.rows, a.rows, "gemm_nt out rows");
    assert_eq!(c.cols, b.rows, "gemm_nt out cols");
    gemm_driver(
        alpha,
        OpView { data: &a.data, ld: a.cols, trans: false },
        OpView { data: &b.data, ld: b.cols, trans: true },
        beta,
        a.rows,
        b.rows,
        a.cols,
        c,
    );
}

/// C = alpha·Aᵀ·B + beta·C (shapes: A k×m, B k×n, C m×n).
pub fn gemm_tn(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "gemm_tn inner dim: {:?}x{:?}", a.shape(), b.shape());
    assert_eq!(c.rows, a.cols, "gemm_tn out rows");
    assert_eq!(c.cols, b.cols, "gemm_tn out cols");
    gemm_driver(
        alpha,
        OpView { data: &a.data, ld: a.cols, trans: true },
        OpView { data: &b.data, ld: b.cols, trans: false },
        beta,
        a.cols,
        b.cols,
        a.rows,
        c,
    );
}

/// C = A · B into a caller-owned buffer (resized in place, allocation
/// reused across calls — the per-step variant for optimizer hot loops).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    c.resize(a.rows, b.cols);
    gemm(1.0, a, b, 0.0, c);
}

/// C = A · Bᵀ into a caller-owned buffer.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    c.resize(a.rows, b.rows);
    gemm_nt(1.0, a, b, 0.0, c);
}

/// C = Aᵀ · B into a caller-owned buffer (projection PᵀG).
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    c.resize(a.cols, b.cols);
    gemm_tn(1.0, a, b, 0.0, c);
}

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul dims {:?}x{:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm(1.0, a, b, 0.0, &mut c);
    c
}

/// C = Aᵀ · B (projection PᵀG): handled by the packing step — no
/// transposed copy is materialized.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn dims");
    let mut c = Matrix::zeros(a.cols, b.cols);
    gemm_tn(1.0, a, b, 0.0, &mut c);
    c
}

/// C = A · Bᵀ.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt dims");
    let mut c = Matrix::zeros(a.rows, b.rows);
    gemm_nt(1.0, a, b, 0.0, &mut c);
    c
}

// ---------------------------------------------------------------------------
// Driver: tile grid + parallel dispatch
// ---------------------------------------------------------------------------

struct SendMut<T>(*mut T);
unsafe impl<T> Sync for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}

thread_local! {
    /// Per-worker packing scratch: [Ap | Bp], grown on demand.
    static SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    m: usize,
    n: usize,
    k: usize,
    c: &mut Matrix,
) {
    debug_assert_eq!(c.data.len(), m * n, "gemm output buffer size");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 || alpha == 0.0 {
        if beta == 0.0 {
            c.data.fill(0.0);
        } else if beta != 1.0 {
            c.scale_in_place(beta);
        }
        return;
    }

    // Tuned path (opt-in): a measured tile choice for this shape
    // class; `None` means tuning is off and the fixed-tiling path
    // below runs exactly as it always has.
    if let Some(cfg) = tune::tile_config(a.trans, b.trans, m, n, k) {
        run_config(alpha, a, b, beta, m, n, k, c, cfg);
        return;
    }

    // Tiny blocks: skip packing (and the thread pool) entirely.
    if 2 * m * n * k <= SMALL_GEMM_FLOPS {
        small_gemm(alpha, a, b, beta, m, n, k, c);
        return;
    }

    blocked_gemm(alpha, a, b, beta, m, n, k, c, MC, KC, NC);
}

/// Run one GEMM with an explicit tile configuration, bypassing both
/// the autotuner and the fixed-path cutover. Public so the tuner's
/// measured search, the tuned-vs-fixed bench, and the determinism
/// tests can pin exact configs. Shapes must already match
/// (`c` is m×n for op(A) m×k · op(B) k×n); same alpha/beta semantics
/// as [`gemm`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_forced(
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
    a_trans: bool,
    b_trans: bool,
    cfg: TileConfig,
) {
    let (m, ka) = if a_trans { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if b_trans { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(ka, kb, "gemm_forced inner dim");
    assert_eq!(c.shape(), (m, n), "gemm_forced out shape");
    if m == 0 || n == 0 {
        return;
    }
    if ka == 0 || alpha == 0.0 {
        if beta == 0.0 {
            c.data.fill(0.0);
        } else if beta != 1.0 {
            c.scale_in_place(beta);
        }
        return;
    }
    run_config(
        alpha,
        OpView { data: &a.data, ld: a.cols, trans: a_trans },
        OpView { data: &b.data, ld: b.cols, trans: b_trans },
        beta,
        m,
        n,
        ka,
        c,
        cfg,
    );
}

/// Dispatch on the kernel variant of a resolved config.
#[allow(clippy::too_many_arguments)]
fn run_config(
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    m: usize,
    n: usize,
    k: usize,
    c: &mut Matrix,
    cfg: TileConfig,
) {
    match cfg.variant {
        KernelVariant::Unpacked => small_gemm(alpha, a, b, beta, m, n, k, c),
        KernelVariant::Blocked => {
            blocked_gemm(alpha, a, b, beta, m, n, k, c, cfg.mc, cfg.kc, cfg.nc)
        }
        KernelVariant::SharedB => {
            shared_b_gemm(alpha, a, b, beta, m, n, k, c, cfg.mc, cfg.kc)
        }
    }
}

/// The packed 2-D-tiled path, parameterized by blocking. `mc0`/`nc0`
/// bound the tile grid (shrunk below for thread coverage); `kc_max`
/// sets the k-slab depth — the one parameter that changes f32
/// rounding, because slab boundaries are reduction split points. For
/// any fixed (mc0, kc_max, nc0) the result is bit-identical across
/// `GUM_THREADS`.
#[allow(clippy::too_many_arguments)]
fn blocked_gemm(
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    m: usize,
    n: usize,
    k: usize,
    c: &mut Matrix,
    mc0: usize,
    kc_max: usize,
    nc0: usize,
) {
    let kc_max = kc_max.clamp(1, k);
    let (kernel, nr) = microkernel();
    // Shrink the tile grid's blocks (powers of two, down to 2·MR/2·nr)
    // until there is at least one tile per thread, so mid-sized shapes
    // still fan out. Block sizes never affect the per-element k-order,
    // so this keeps results bit-identical across thread counts.
    let threads = num_threads();
    let mut mc = mc0.max(MR).min(m.next_multiple_of(MR));
    let mut nc = nc0.max(nr).min(n.next_multiple_of(nr));
    while m.div_ceil(mc) * n.div_ceil(nc) < threads {
        if mc >= nc && mc > 2 * MR {
            mc /= 2;
        } else if nc > 2 * nr {
            nc /= 2;
        } else if mc > 2 * MR {
            mc /= 2;
        } else {
            break;
        }
    }

    let m_tiles = m.div_ceil(mc);
    let n_tiles = n.div_ceil(nc);
    let tile_flops = 2 * mc.min(m) * nc.min(n) * k;
    let min_chunk = (PAR_MIN_FLOPS / tile_flops.max(1)).max(1);
    let c_ptr = SendMut(c.data.as_mut_ptr());

    parallel_chunks(m_tiles * n_tiles, min_chunk, |t0, t1| {
        let c_ptr = &c_ptr;
        SCRATCH.with(|scratch| {
            let mut scratch = scratch.borrow_mut();
            let ap_len = mc.div_ceil(MR) * MR * kc_max;
            let bp_len = nc.div_ceil(nr) * nr * kc_max;
            if scratch.len() < ap_len + bp_len {
                scratch.resize(ap_len + bp_len, 0.0);
            }
            let (ap, bp) = scratch.split_at_mut(ap_len);
            for t in t0..t1 {
                let ic = (t % m_tiles) * mc;
                let jc = (t / m_tiles) * nc;
                let tile = Tile {
                    ic,
                    mc: mc.min(m - ic),
                    jc,
                    nc: nc.min(n - jc),
                };
                process_tile(
                    kernel, nr, alpha, a, b, beta, k, kc_max, n, &tile, ap,
                    bp, c_ptr.0,
                );
            }
        });
    });
}

/// The shared-B packed path: op(B) is packed **once**, in full
/// (KC-slab-major, NR-column panels — the exact layout [`pack_b`]
/// produces for the blocked path), then row tiles fan out 1-D over the
/// pool and each tile packs only its own op(A) slab. The blocked path
/// repacks B's panels once per row tile; for narrow-k projection
/// shapes (k = r ≤ 512, so one slab) that redundancy dominates, which
/// is exactly the family this variant targets.
///
/// Per C element the contribution order is KC slabs ascending, k
/// ascending within a slab — identical to [`blocked_gemm`] with the
/// same `kc_max`, so the two variants are bitwise-interchangeable for
/// equal `kc` (asserted in tests) and equally thread-count-invariant.
#[allow(clippy::too_many_arguments)]
fn shared_b_gemm(
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    m: usize,
    n: usize,
    k: usize,
    c: &mut Matrix,
    mc0: usize,
    kc_max: usize,
) {
    let kc_max = kc_max.clamp(1, k);
    let (kernel, nr) = microkernel();
    let n_panels = n.div_ceil(nr);
    let n_slabs = k.div_ceil(kc_max);
    let slab_stride = n_panels * nr * kc_max;
    let mut bp_all = vec![0.0f32; slab_stride * n_slabs];
    for (s, dst) in bp_all.chunks_exact_mut(slab_stride).enumerate() {
        let pc = s * kc_max;
        let kc = kc_max.min(k - pc);
        pack_b(b, pc, kc, 0, n, nr, dst);
    }
    let bp_all = &bp_all;

    let mc = mc0.max(MR).min(m.next_multiple_of(MR));
    let m_tiles = m.div_ceil(mc);
    let tile_flops = 2 * mc.min(m) * n * k;
    let min_chunk = (PAR_MIN_FLOPS / tile_flops.max(1)).max(1);
    let c_ptr = SendMut(c.data.as_mut_ptr());

    parallel_chunks(m_tiles, min_chunk, |t0, t1| {
        let c_ptr = &c_ptr;
        SCRATCH.with(|scratch| {
            let mut scratch = scratch.borrow_mut();
            let ap_len = mc.div_ceil(MR) * MR * kc_max;
            if scratch.len() < ap_len {
                scratch.resize(ap_len, 0.0);
            }
            let ap = &mut scratch[..ap_len];
            for t in t0..t1 {
                let ic = t * mc;
                let mc_t = mc.min(m - ic);
                // Beta prescale of this tile's row band (exclusive to
                // this thread — tiles partition the rows).
                for i in 0..mc_t {
                    // SAFETY: rows ic..ic+mc_t belong to this tile only.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            c_ptr.0.add((ic + i) * n),
                            n,
                        )
                    };
                    if beta == 0.0 {
                        row.fill(0.0);
                    } else if beta != 1.0 {
                        for v in row.iter_mut() {
                            *v *= beta;
                        }
                    }
                }
                let m_panels = mc_t.div_ceil(MR);
                for s in 0..n_slabs {
                    let pc = s * kc_max;
                    let kc = kc_max.min(k - pc);
                    pack_a(a, ic, mc_t, pc, kc, ap);
                    let bp = &bp_all[s * slab_stride..];
                    for jp in 0..n_panels {
                        let b_panel = &bp[jp * nr * kc..(jp + 1) * nr * kc];
                        let j0 = jp * nr;
                        let ncols = nr.min(n - j0);
                        for ip in 0..m_panels {
                            let a_panel =
                                &ap[ip * MR * kc..(ip + 1) * MR * kc];
                            let i0 = ic + ip * MR;
                            let nrows = MR.min(ic + mc_t - i0);
                            let mut acc = [0.0f32; MR * NR_MAX];
                            // SAFETY: dispatch checked CPU features.
                            unsafe { kernel(kc, a_panel, b_panel, &mut acc) };
                            for (r, a_row) in
                                acc.chunks_exact(nr).take(nrows).enumerate()
                            {
                                // SAFETY: within this tile's rows.
                                let c_row = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        c_ptr.0.add((i0 + r) * n + j0),
                                        ncols,
                                    )
                                };
                                if alpha == 1.0 {
                                    for (cv, &av) in
                                        c_row.iter_mut().zip(a_row)
                                    {
                                        *cv += av;
                                    }
                                } else {
                                    for (cv, &av) in
                                        c_row.iter_mut().zip(a_row)
                                    {
                                        *cv += alpha * av;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        });
    });
}

/// One mc×nc tile of C, owned by a single thread.
struct Tile {
    ic: usize,
    mc: usize,
    jc: usize,
    nc: usize,
}

/// Process one C tile: beta prescale, then KC-slab loop of
/// pack-pack-microkernel.
///
/// SAFETY: callers pass tiles with pairwise-disjoint (ic, jc) ranges,
/// so the raw writes through `c` never overlap across threads.
#[allow(clippy::too_many_arguments)]
fn process_tile(
    kernel: MicroKernel,
    nr: usize,
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    k: usize,
    kc_max: usize,
    ldc: usize,
    tile: &Tile,
    ap: &mut [f32],
    bp: &mut [f32],
    c: *mut f32,
) {
    let Tile { ic, mc, jc, nc } = *tile;
    for i in 0..mc {
        // SAFETY: rows ic..ic+mc / cols jc..jc+nc are exclusive to this
        // tile (see fn-level contract).
        let row = unsafe {
            std::slice::from_raw_parts_mut(c.add((ic + i) * ldc + jc), nc)
        };
        if beta == 0.0 {
            row.fill(0.0);
        } else if beta != 1.0 {
            for v in row.iter_mut() {
                *v *= beta;
            }
        }
    }

    let m_panels = mc.div_ceil(MR);
    let n_panels = nc.div_ceil(nr);
    let mut pc = 0;
    while pc < k {
        let kc = kc_max.min(k - pc);
        pack_b(b, pc, kc, jc, nc, nr, bp);
        pack_a(a, ic, mc, pc, kc, ap);
        for jp in 0..n_panels {
            let b_panel = &bp[jp * nr * kc..(jp + 1) * nr * kc];
            let j0 = jc + jp * nr;
            let ncols = nr.min(jc + nc - j0);
            for ip in 0..m_panels {
                let a_panel = &ap[ip * MR * kc..(ip + 1) * MR * kc];
                let i0 = ic + ip * MR;
                let nrows = MR.min(ic + mc - i0);
                let mut acc = [0.0f32; MR * NR_MAX];
                // SAFETY: dispatch checked the required CPU features.
                unsafe { kernel(kc, a_panel, b_panel, &mut acc) };
                for (r, a_row) in acc.chunks_exact(nr).take(nrows).enumerate()
                {
                    // SAFETY: within this tile's exclusive C region.
                    let c_row = unsafe {
                        std::slice::from_raw_parts_mut(
                            c.add((i0 + r) * ldc + j0),
                            ncols,
                        )
                    };
                    if alpha == 1.0 {
                        for (cv, &av) in c_row.iter_mut().zip(a_row) {
                            *cv += av;
                        }
                    } else {
                        for (cv, &av) in c_row.iter_mut().zip(a_row) {
                            *cv += alpha * av;
                        }
                    }
                }
            }
        }
        pc += kc;
    }
}

// ---------------------------------------------------------------------------
// Small-shape kernel (no packing, no dispatch)
// ---------------------------------------------------------------------------

/// Unpacked serial GEMM for shapes below [`SMALL_GEMM_FLOPS`]: the
/// transposes are folded into the loop order (never materialized), the
/// k-axis sums ascend exactly as in the packed path's slabs, and each C
/// element is written by one serial loop — deterministic by
/// construction.
#[allow(clippy::too_many_arguments)]
fn small_gemm(
    alpha: f32,
    a: OpView,
    b: OpView,
    beta: f32,
    m: usize,
    n: usize,
    k: usize,
    c: &mut Matrix,
) {
    if beta == 0.0 {
        c.data.fill(0.0);
    } else if beta != 1.0 {
        c.scale_in_place(beta);
    }
    match (a.trans, b.trans) {
        // NN: stream B rows into each C row (axpy per k).
        (false, false) => {
            for i in 0..m {
                let c_row = &mut c.data[i * n..(i + 1) * n];
                for kk in 0..k {
                    let av = alpha * a.data[i * a.ld + kk];
                    let b_row = &b.data[kk * b.ld..kk * b.ld + n];
                    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                        *cv += av * bv;
                    }
                }
            }
        }
        // NT: contiguous dot products (both operands row-major over k).
        (false, true) => {
            for i in 0..m {
                let a_row = &a.data[i * a.ld..i * a.ld + k];
                let c_row = &mut c.data[i * n..(i + 1) * n];
                for (j, cv) in c_row.iter_mut().enumerate() {
                    let b_row = &b.data[j * b.ld..j * b.ld + k];
                    *cv += alpha * dot(a_row, b_row);
                }
            }
        }
        // TN: op(A)[i, kk] = A[kk, i] — strided A reads, streaming B
        // rows (no transposed copy).
        (true, false) => {
            for i in 0..m {
                let c_row = &mut c.data[i * n..(i + 1) * n];
                for kk in 0..k {
                    let av = alpha * a.data[kk * a.ld + i];
                    let b_row = &b.data[kk * b.ld..kk * b.ld + n];
                    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                        *cv += av * bv;
                    }
                }
            }
        }
        // TT: not produced by the public entry points; correctness-only.
        (true, true) => {
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for kk in 0..k {
                        s += a.data[kk * a.ld + i] * b.data[j * b.ld + kk];
                    }
                    c.data[i * n + j] += alpha * s;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack op(A)[ic..ic+mc, pc..pc+kc] into MR-row panels:
/// `ap[p·MR·kc + k·MR + r] = op(A)[ic + p·MR + r, pc + k]`,
/// zero-padded to the MR grid so the microkernel needs no row bounds.
fn pack_a(a: OpView, ic: usize, mc: usize, pc: usize, kc: usize, ap: &mut [f32]) {
    debug_assert!(ap.len() >= mc.div_ceil(MR) * MR * kc, "A scratch too small");
    for p in 0..mc.div_ceil(MR) {
        let dst = &mut ap[p * MR * kc..(p + 1) * MR * kc];
        let i0 = ic + p * MR;
        let rows = MR.min(ic + mc - i0);
        if a.trans {
            // op(A)[i, kk] = A[kk, i]: the i-axis is contiguous.
            for kk in 0..kc {
                let src = &a.data[(pc + kk) * a.ld + i0..][..rows];
                let d = &mut dst[kk * MR..(kk + 1) * MR];
                d[..rows].copy_from_slice(src);
                d[rows..].fill(0.0);
            }
        } else {
            for r in 0..rows {
                let src = &a.data[(i0 + r) * a.ld + pc..][..kc];
                for (kk, &v) in src.iter().enumerate() {
                    dst[kk * MR + r] = v;
                }
            }
            if rows < MR {
                for kk in 0..kc {
                    dst[kk * MR + rows..(kk + 1) * MR].fill(0.0);
                }
            }
        }
    }
}

/// Pack op(B)[pc..pc+kc, jc..jc+nc] into `nr`-column panels:
/// `bp[p·nr·kc + k·nr + c] = op(B)[pc + k, jc + p·nr + c]`,
/// zero-padded to the `nr` grid (`nr` is the microkernel's B-panel
/// width — [`NR`] or [`NR_MAX`] depending on the resolved ISA path).
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: OpView,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    nr: usize,
    bp: &mut [f32],
) {
    debug_assert!(bp.len() >= nc.div_ceil(nr) * nr * kc, "B scratch too small");
    for p in 0..nc.div_ceil(nr) {
        let dst = &mut bp[p * nr * kc..(p + 1) * nr * kc];
        let j0 = jc + p * nr;
        let cols = nr.min(jc + nc - j0);
        if b.trans {
            // op(B)[kk, j] = B[j, kk]: the k-axis is contiguous.
            for cc in 0..cols {
                let src = &b.data[(j0 + cc) * b.ld + pc..][..kc];
                for (kk, &v) in src.iter().enumerate() {
                    dst[kk * nr + cc] = v;
                }
            }
            if cols < nr {
                for kk in 0..kc {
                    dst[kk * nr + cols..(kk + 1) * nr].fill(0.0);
                }
            }
        } else {
            for kk in 0..kc {
                let src = &b.data[(pc + kk) * b.ld + j0..][..cols];
                let d = &mut dst[kk * nr..(kk + 1) * nr];
                d[..cols].copy_from_slice(src);
                d[cols..].fill(0.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Register microkernel
// ---------------------------------------------------------------------------

type MicroKernel = unsafe fn(usize, &[f32], &[f32], &mut [f32; MR * NR_MAX]);

/// `acc[r, c] += Σ_k Ap[k, r] · Bp[k, c]` over one packed panel pair.
/// The accumulator tile lives in registers (MR rows of `NR_K` lanes,
/// packed at stride `NR_K` into the width-independent `MR·NR_MAX`
/// array); `FMA` selects `mul_add` so the SIMD specializations
/// contract to vfmadd without imposing libm calls on the generic path.
/// Per (r, c) the k-loop order is identical for every `NR_K`, so panel
/// width never perturbs bits — only the ISA path's FMA contraction can.
#[inline(always)]
fn microkernel_body<const FMA: bool, const NR_K: usize>(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [f32; MR * NR_MAX],
) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR_K, "panel size");
    for (a_col, b_row) in
        ap.chunks_exact(MR).zip(bp.chunks_exact(NR_K)).take(kc)
    {
        for (r, &ar) in a_col.iter().enumerate() {
            let row = &mut acc[r * NR_K..(r + 1) * NR_K];
            for (cv, &bv) in row.iter_mut().zip(b_row) {
                *cv = if FMA { ar.mul_add(bv, *cv) } else { *cv + ar * bv };
            }
        }
    }
}

/// Portable fallback (also the non-x86 path).
///
/// SAFETY: no requirements; unsafe only to share the fn-pointer type
/// with the feature-gated specializations.
unsafe fn microkernel_generic(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [f32; MR * NR_MAX],
) {
    microkernel_body::<false, NR>(kc, ap, bp, acc)
}

/// AVX2+FMA specialization: same body, compiled with 8-lane f32 and
/// fused multiply-add enabled.
///
/// SAFETY: callers must have verified avx2 and fma support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn microkernel_avx2(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [f32; MR * NR_MAX],
) {
    microkernel_body::<true, NR>(kc, ap, bp, acc)
}

/// AVX-512 specialization: the same body again, with 16-wide B panels
/// so each accumulator row is exactly one zmm register.
///
/// SAFETY: callers must have verified avx512f and avx512bw support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx512bw")]
unsafe fn microkernel_avx512(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    acc: &mut [f32; MR * NR_MAX],
) {
    microkernel_body::<true, NR_MAX>(kc, ap, bp, acc)
}

/// Resolve the microkernel and its B-panel width once per process (the
/// cached CPU probe in [`super::isa`] is shared with the elementwise
/// and lowp engines). The choice is global, so every thread — and
/// every `GUM_THREADS` setting — runs identical arithmetic.
fn microkernel() -> (MicroKernel, usize) {
    #[cfg(target_arch = "x86_64")]
    match super::isa::level() {
        super::isa::IsaLevel::Avx512 => {
            return (microkernel_avx512 as MicroKernel, NR_MAX)
        }
        super::isa::IsaLevel::Avx2 => {
            return (microkernel_avx2 as MicroKernel, NR)
        }
        super::isa::IsaLevel::Portable => {}
    }
    (microkernel_generic as MicroKernel, NR)
}

/// Accumulating dot product, 16-lane accumulators for auto-vectorization.
/// Kept for vector callers (the GEMM paths now go through the packed
/// kernel).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let lanes = n / 16 * 16;
    let mut acc = [0.0f32; 16];
    let (ah, at) = a.split_at(lanes);
    let (bh, bt) = b.split_at(lanes);
    for (aa, bb) in ah.chunks_exact(16).zip(bh.chunks_exact(16)) {
        for l in 0..16 {
            acc[l] += aa[l] * bb[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;
    use crate::thread::set_num_threads;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg::new(0);
        for (m, k, n) in [
            (1, 1, 1),
            (3, 5, 2),
            (17, 33, 9),
            (64, 64, 64),
            // Straddle the MR/NR/MC/KC edges.
            (7, 257, 9),
            (129, 31, 65),
            (8, 8, 8),
        ] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Pcg::new(1);
        let a = Matrix::randn(8, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 10, 1.0, &mut rng);
        let c0 = Matrix::randn(8, 10, 1.0, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c);
        let mut want = naive(&a, &b);
        want.scale_in_place(2.0);
        want.add_scaled_in_place(0.5, &c0);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg::new(2);
        let a = Matrix::randn(23, 11, 1.0, &mut rng);
        let b = Matrix::randn(23, 17, 1.0, &mut rng);
        let tn = matmul_tn(&a, &b);
        let want = matmul(&a.transpose(), &b);
        assert!(tn.max_abs_diff(&want) < 1e-4);

        let c = Matrix::randn(9, 23, 1.0, &mut rng);
        let d = Matrix::randn(31, 23, 1.0, &mut rng);
        let nt = matmul_nt(&c, &d);
        let want = matmul(&c, &d.transpose());
        assert!(nt.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let mut rng = Pcg::new(5);
        let a = Matrix::randn(13, 21, 1.0, &mut rng);
        let b = Matrix::randn(21, 7, 1.0, &mut rng);
        let mut c = Matrix::zeros(1, 1); // wrong shape: resized in place
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.shape(), (13, 7));
        assert!(c.max_abs_diff(&matmul(&a, &b)) == 0.0);

        matmul_tn_into(&a, &a, &mut c);
        assert_eq!(c.shape(), (21, 21));
        assert!(c.max_abs_diff(&matmul_tn(&a, &a)) == 0.0);

        matmul_nt_into(&a, &a, &mut c);
        assert_eq!(c.shape(), (13, 13));
        assert!(c.max_abs_diff(&matmul_nt(&a, &a)) == 0.0);
    }

    #[test]
    fn degenerate_dims_are_safe() {
        // Zero-sized m/n/k and 1×1 all produce well-defined results.
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        assert_eq!(matmul(&a, &b).shape(), (0, 3));

        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 3);
        let c = matmul(&a, &b); // k = 0 → all zeros
        assert_eq!(c.shape(), (4, 3));
        assert!(c.data.iter().all(|&v| v == 0.0));

        // k = 0 with beta keeps the scaled C.
        let mut c = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let a = Matrix::zeros(2, 0);
        let b = Matrix::zeros(0, 2);
        gemm(1.0, &a, &b, 0.5, &mut c);
        assert_eq!(c.data, vec![0.5, 1.0, 1.5, 2.0]);

        let one = Matrix::from_vec(1, 1, vec![3.0]);
        assert_eq!(matmul(&one, &one).data, vec![9.0]);
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Pcg::new(3);
        let a = Matrix::randn(130, 70, 1.0, &mut rng);
        let b = Matrix::randn(70, 90, 1.0, &mut rng);
        let orig = set_num_threads(1);
        let serial = matmul(&a, &b);
        for t in [2usize, 4, 16] {
            set_num_threads(t);
            let par = matmul(&a, &b);
            assert_eq!(serial.data, par.data, "threads {t}");
        }
        set_num_threads(orig);
    }

    #[test]
    fn small_shape_cutover_agrees_with_packed_path() {
        // Shapes straddling SMALL_GEMM_FLOPS: 64×64×32 (2¹⁸ FLOPs) takes
        // the unpacked kernel, 64³ (2¹⁹) the packed one; both must match
        // the f64 reference in every op orientation, including the
        // alpha/beta accumulate form.
        let mut rng = Pcg::new(11);
        for (m, k, n) in [(64usize, 32usize, 64usize), (64, 64, 64), (65, 33, 63)]
        {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let want = naive(&a, &b);
            assert!(
                matmul(&a, &b).max_abs_diff(&want) < 1e-3,
                "nn {m}x{k}x{n}"
            );
            let tn = matmul_tn(&a.transpose(), &b);
            assert!(tn.max_abs_diff(&want) < 1e-3, "tn {m}x{k}x{n}");
            let nt = matmul_nt(&a, &b.transpose());
            assert!(nt.max_abs_diff(&want) < 1e-3, "nt {m}x{k}x{n}");

            let c0 = Matrix::randn(m, n, 1.0, &mut rng);
            let mut c = c0.clone();
            gemm(2.0, &a, &b, 0.5, &mut c);
            let mut acc = want.scaled(2.0);
            acc.add_scaled_in_place(0.5, &c0);
            assert!(c.max_abs_diff(&acc) < 1e-3, "acc {m}x{k}x{n}");
        }
    }

    #[test]
    fn forced_variants_match_reference() {
        // Every kernel variant the tuner can pick must agree with the
        // f64 reference in every orientation, including ragged edges.
        let mut rng = Pcg::new(21);
        let configs = [
            TileConfig::unpacked(),
            TileConfig::blocked(64, 64, 128),
            TileConfig::blocked(128, 256, 512),
            TileConfig::shared_b(64, 64),
            TileConfig::shared_b(128, 37), // ragged slab split
        ];
        for (m, k, n) in [(65usize, 33usize, 130usize), (128, 64, 96)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let want = naive(&a, &b);
            let at = a.transpose();
            let bt = b.transpose();
            for cfg in configs {
                let mut c = Matrix::zeros(m, n);
                gemm_forced(1.0, &a, &b, 0.0, &mut c, false, false, cfg);
                assert!(c.max_abs_diff(&want) < 1e-3, "nn {cfg:?}");
                gemm_forced(1.0, &at, &b, 0.0, &mut c, true, false, cfg);
                assert!(c.max_abs_diff(&want) < 1e-3, "tn {cfg:?}");
                gemm_forced(1.0, &a, &bt, 0.0, &mut c, false, true, cfg);
                assert!(c.max_abs_diff(&want) < 1e-3, "nt {cfg:?}");
            }
        }
    }

    #[test]
    fn shared_b_is_bitwise_equal_to_blocked_for_same_kc() {
        // The two packed variants keep the same per-element summation
        // order for equal kc, so swapping variant (what the tuner does)
        // never perturbs bits — only kc can.
        let mut rng = Pcg::new(22);
        let a = Matrix::randn(130, 96, 1.0, &mut rng);
        let b = Matrix::randn(96, 150, 1.0, &mut rng);
        for kc in [37usize, 64, 96] {
            let mut blocked = Matrix::zeros(130, 150);
            gemm_forced(
                1.0, &a, &b, 0.0, &mut blocked, false, false,
                TileConfig::blocked(64, kc, 128),
            );
            let mut shared = Matrix::zeros(130, 150);
            gemm_forced(
                1.0, &a, &b, 0.0, &mut shared, false, false,
                TileConfig::shared_b(64, kc),
            );
            assert_eq!(blocked.data, shared.data, "kc {kc}");
        }
    }

    #[test]
    fn forced_variants_are_thread_count_invariant() {
        let mut rng = Pcg::new(23);
        let a = Matrix::randn(140, 70, 1.0, &mut rng);
        let b = Matrix::randn(70, 120, 1.0, &mut rng);
        for cfg in [
            TileConfig::blocked(64, 48, 64),
            TileConfig::shared_b(64, 48),
        ] {
            let orig = set_num_threads(1);
            let mut serial = Matrix::zeros(140, 120);
            gemm_forced(1.0, &a, &b, 0.0, &mut serial, false, false, cfg);
            for t in [2usize, 8] {
                set_num_threads(t);
                let mut par = Matrix::zeros(140, 120);
                gemm_forced(1.0, &a, &b, 0.0, &mut par, false, false, cfg);
                assert_eq!(serial.data, par.data, "{cfg:?} threads {t}");
            }
            set_num_threads(orig);
        }
    }

    #[test]
    fn dot_basic() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..19).map(|i| (i * 2) as f32).collect();
        let want: f32 = (0..19).map(|i| (i * i * 2) as f32).sum();
        assert_eq!(dot(&a, &b), want);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg::new(3);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        let i = Matrix::eye(12);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "gemm out rows")]
    fn gemm_rejects_mismatched_output() {
        let a = Matrix::zeros(4, 3);
        let b = Matrix::zeros(3, 5);
        let mut c = Matrix::zeros(9, 5);
        gemm(1.0, &a, &b, 0.0, &mut c);
    }
}
