//! # GUM — GaLore Unbiased with Muon
//!
//! Production reproduction of *Unbiased Gradient Low-Rank Projection*
//! (CS.LG 2025): memory-efficient LLM training via debiased gradient
//! low-rank projection with layerwise sampling, Muon as the base
//! optimizer.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! - **L3 (this crate)** — training coordinator: layerwise sampling
//!   scheduler, period/projector management, per-block optimizer state,
//!   memory accounting, data pipeline, metrics, CLI.
//! - **L2** — JAX transformer fwd/bwd, AOT-lowered to HLO text at build
//!   time (`python/compile/`), executed here via PJRT (`runtime`).
//! - **L1** — Pallas kernels (Newton–Schulz, low-rank projection) lowered
//!   into the same artifacts.
//!
//! The offline registry only carries the `xla` crate closure, so common
//! infrastructure (JSON, CLI parsing, bench harness, property testing,
//! thread pool, PRNG) is implemented in-tree as first-class substrates.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod synthetic;
pub mod testing;
pub mod thread;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
