//! Minimal property-testing substrate (offline registry has no proptest).
//!
//! `check(n, |g| { ... })` runs a property `n` times with independent
//! seeded generators; failures report the seed so the case replays with
//! `check_seed`. Generators cover the numeric/shape inputs the linalg,
//! optimizer and coordinator invariants need. Failures panic with a
//! structured [`PropFailure`] payload (never a bare string) that also
//! records whether the underlying panic was a planned
//! [`faults::InjectedFault`] — so fault-injection suites can tell a
//! deliberately killed lane from a real bug in their output.
//!
//! [`faults`] hosts the deterministic fault-injection plans the elastic
//! trainer and its recovery suites drive.

pub mod faults;

use std::fmt;

use crate::rng::Pcg;

pub use faults::{
    describe_panic, Fault, FaultPlan, FaultPlanArtifact, InjectedFault,
};

/// Structured panic payload for a failed property: the failing case and
/// seed (replay coordinates), whether the inner panic was an injected
/// fault, and the inner message. `Display` renders the replay hint the
/// old string panic carried.
#[derive(Debug, Clone)]
pub struct PropFailure {
    pub case: u64,
    pub seed: u64,
    /// Base seed of the whole run (`GUM_PROP_SEED` replay value).
    pub base: u64,
    /// True when the inner panic carried an [`InjectedFault`] payload.
    pub injected: bool,
    pub message: String,
}

impl fmt::Display for PropFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let tag = if self.injected { "[injected fault] " } else { "" };
        write!(
            f,
            "property failed on case {} (seed {:#x}): {tag}{}\n\
             replay: GUM_PROP_SEED={} (case {}) or \
             testing::check_seed({:#x}, prop)",
            self.case, self.seed, self.message, self.base, self.case, self.seed
        )
    }
}

impl std::error::Error for PropFailure {}

/// Input generator handed to properties; wraps a seeded PRNG with
/// size-biased helpers.
pub struct Gen {
    pub rng: Pcg,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Pcg::new(seed),
            seed,
        }
    }

    /// Dimension in [lo, hi], biased toward small values (shrinking-lite:
    /// early iterations use small sizes, so the first failure tends to be
    /// near-minimal).
    pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi && lo > 0);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn prob(&mut self) -> f64 {
        // Away from exact 0/1 to keep 1/q finite in debias math.
        0.05 + 0.9 * self.rng.f64()
    }

    pub fn matrix(&mut self, rows: usize, cols: usize) -> crate::linalg::Matrix {
        crate::linalg::Matrix::randn(rows, cols, 1.0, &mut self.rng)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
}

/// Run `prop` for `cases` seeds; panics with the failing seed on error.
pub fn check<F: FnMut(&mut Gen)>(cases: u64, mut prop: F) {
    // Base seed overridable for replay of a whole run.
    let base: u64 = std::env::var("GUM_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x9e3779b97f4a7c15);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x2545F4914F6CDD1D));
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut g),
        ));
        if let Err(payload) = result {
            let (injected, message) = describe_panic(payload.as_ref());
            let failure = PropFailure {
                case,
                seed,
                base,
                injected,
                message,
            };
            // The default panic hook cannot render a typed payload, so
            // print the replay coordinates before unwinding — the seed
            // must always reach the test log.
            eprintln!("{failure}");
            std::panic::panic_any(failure);
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F: FnOnce(&mut Gen)>(seed: u64, prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

/// Assert two f32 slices are close (abs+rel tolerance).
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            err <= tol * scale,
            "{ctx}: index {i}: {x} vs {y} (err {err}, tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check(25, |_g| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check(10, |g| {
                // Fails whenever dim >= 2 — virtually immediately.
                assert!(g.dim(1, 100) < 2, "too big");
            });
        });
        let err = result.expect_err("must fail");
        let failure = err
            .downcast_ref::<PropFailure>()
            .expect("payload must be a structured PropFailure");
        assert!(!failure.injected, "an assert! is a real bug, not a fault");
        let msg = failure.to_string();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("too big"), "{msg}");
    }

    #[test]
    fn injected_faults_are_flagged_in_failures() {
        let result = std::panic::catch_unwind(|| {
            check(1, |_g| {
                std::panic::panic_any(InjectedFault { lane: 2, step: 7 });
            });
        });
        let err = result.expect_err("must fail");
        let failure = err.downcast_ref::<PropFailure>().unwrap();
        assert!(failure.injected, "typed payload must be recognized");
        assert!(failure.to_string().contains("[injected fault]"));
        assert!(failure.message.contains("lane 2"));
    }

    #[test]
    fn assert_close_tolerates_and_rejects() {
        assert_close(&[1.0, 2.0], &[1.0001, 2.0001], 1e-3, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_close(&[1.0], &[1.5], 1e-3, "bad")
        });
        assert!(r.is_err());
    }
}
