//! Deterministic fault injection for the elastic trainer.
//!
//! A [`FaultPlan`] is a seeded, schedulable list of faults — lane kills,
//! slow-lane stalls, checkpoint-write truncations — that the supervision
//! layer (`coordinator::elastic`) and the trainer thread through the
//! gradient lanes and the snapshot writer. Two properties make plans
//! usable for determinism testing:
//!
//! 1. **One-shot firing.** Every fault fires at most once per plan
//!    instance (interior-mutable fired set, shared through the `Arc`
//!    every lane holds), so recovery replays of the same step do not
//!    re-trigger the fault and the run converges.
//! 2. **Structured payloads.** Injected kills panic with (or return) a
//!    typed [`InjectedFault`] — never a bare string — so supervisors and
//!    test harnesses can distinguish a *planned* fault from a real bug
//!    unwinding out of the gradient engine.
//!
//! Plans round-trip through a compact spec string (the `--fault-plan`
//! CLI surface, comma-separated):
//!
//! ```text
//! kill:<lane>@<step>            lane panics at global step
//! stall:<lane>@<step>:<millis>  lane sleeps before computing
//! trunc:<nth>@<keep>            nth train-state save truncated to keep bytes
//! ```

use std::fmt;
use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::rng::{derive_seed, Pcg};

/// Typed payload for a planned lane kill. Carried through `panic_any`
/// (pool paths) or as an error source (`Result` paths) so injected
/// faults are distinguishable from real bugs wherever they surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InjectedFault {
    pub lane: usize,
    pub step: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected fault: lane {} killed at step {}",
            self.lane, self.step
        )
    }
}

impl std::error::Error for InjectedFault {}

/// One schedulable fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Lane `lane` panics (with an [`InjectedFault`] payload) at global
    /// step `step`, on the first attempt of that step only.
    Kill { lane: usize, step: u64 },
    /// Lane `lane` sleeps `millis` before computing at global step
    /// `step` — a slow-lane straggler, not a failure.
    Stall { lane: usize, step: u64, millis: u64 },
    /// The `nth` (0-based) train-state save of the run is truncated to
    /// `keep` bytes *after* its atomic commit — a simulated torn write
    /// that the corrupt-tail recovery path must survive.
    Truncate { nth_save: u64, keep: u64 },
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::Kill { lane, step } => write!(f, "kill:{lane}@{step}"),
            Fault::Stall { lane, step, millis } => {
                write!(f, "stall:{lane}@{step}:{millis}")
            }
            Fault::Truncate { nth_save, keep } => {
                write!(f, "trunc:{nth_save}@{keep}")
            }
        }
    }
}

/// What a `poll` of the plan asks the caller to do.
enum Action {
    Kill(InjectedFault),
    Stall(u64),
}

/// A seeded, schedulable set of one-shot faults (see module docs).
pub struct FaultPlan {
    faults: Vec<Fault>,
    /// One flag per fault; set when the fault has fired. Lock is
    /// poison-tolerant because kills unwind lanes on pool threads.
    fired: Mutex<Vec<bool>>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        let fired = Mutex::new(vec![false; faults.len()]);
        FaultPlan { faults, fired }
    }

    /// A plan with no faults (the fault-free fast path).
    pub fn empty() -> FaultPlan {
        FaultPlan::new(Vec::new())
    }

    /// `n_kills` lane kills at seed-derived (lane, step) slots — the
    /// randomized arm of the fault matrix.
    pub fn seeded(seed: u64, lanes: usize, max_step: u64, n_kills: usize) -> FaultPlan {
        assert!(lanes >= 1 && max_step >= 1);
        let mut rng = Pcg::new(derive_seed(seed, "fault-plan"));
        let mut faults = Vec::with_capacity(n_kills);
        for _ in 0..n_kills {
            faults.push(Fault::Kill {
                lane: rng.below(lanes),
                step: rng.below(max_step as usize) as u64,
            });
        }
        FaultPlan::new(faults)
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// How many faults have fired so far.
    pub fn fired_count(&self) -> usize {
        self.lock_fired().iter().filter(|f| **f).count()
    }

    /// Parse the `--fault-plan` spec grammar (see module docs). Empty
    /// and whitespace-only specs yield the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, rest) = clause
                .split_once(':')
                .with_context(|| format!("fault clause '{clause}': expected kind:args"))?;
            match kind {
                "kill" => {
                    let (lane, step) = parse_at(rest)
                        .with_context(|| format!("fault clause '{clause}'"))?;
                    faults.push(Fault::Kill {
                        lane: lane as usize,
                        step,
                    });
                }
                "stall" => {
                    let (head, millis) = rest.rsplit_once(':').with_context(|| {
                        format!("fault clause '{clause}': expected stall:lane@step:millis")
                    })?;
                    let (lane, step) = parse_at(head)
                        .with_context(|| format!("fault clause '{clause}'"))?;
                    let millis: u64 = millis.parse().with_context(|| {
                        format!("fault clause '{clause}': bad millis '{millis}'")
                    })?;
                    faults.push(Fault::Stall {
                        lane: lane as usize,
                        step,
                        millis,
                    });
                }
                "trunc" => {
                    let (nth_save, keep) = parse_at(rest)
                        .with_context(|| format!("fault clause '{clause}'"))?;
                    faults.push(Fault::Truncate { nth_save, keep });
                }
                other => bail!(
                    "unknown fault kind '{other}' in '{clause}' (expected kill|stall|trunc)"
                ),
            }
        }
        Ok(FaultPlan::new(faults))
    }

    /// The spec string this plan parses back from (replay surface).
    pub fn spec(&self) -> String {
        self.faults
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Fire any unfired faults scheduled for `(lane, step)`, **panicking**
    /// with an [`InjectedFault`] payload on a kill — the path threaded
    /// through [`crate::coordinator::SyntheticGradSource`], where the
    /// unwind genuinely originates inside a gradient lane on a pool
    /// thread. Stalls sleep and return.
    pub fn fire(&self, lane: usize, step: u64) {
        for action in self.poll(lane, step) {
            match action {
                Action::Stall(millis) => {
                    std::thread::sleep(Duration::from_millis(millis));
                }
                Action::Kill(fault) => std::panic::panic_any(fault),
            }
        }
    }

    /// [`FaultPlan::fire`] for `Result`-based lanes (the sequential PJRT
    /// trainer): kills come back as a typed error instead of an unwind.
    pub fn check(&self, lane: usize, step: u64) -> std::result::Result<(), InjectedFault> {
        for action in self.poll(lane, step) {
            match action {
                Action::Stall(millis) => {
                    std::thread::sleep(Duration::from_millis(millis));
                }
                Action::Kill(fault) => return Err(fault),
            }
        }
        Ok(())
    }

    /// Apply a scheduled truncation for the `nth` (0-based) train-state
    /// save to the *committed* file — the torn-write simulation both the
    /// elastic supervisor and the trainer run right after their atomic
    /// save. Returns true when a truncation fired.
    pub fn apply_truncation(&self, nth: u64, path: &Path) -> Result<bool> {
        match self.truncation_for_save(nth) {
            None => Ok(false),
            Some(keep) => {
                crate::warn!(
                    "fault plan: truncating {} to {keep} bytes (torn write)",
                    path.display()
                );
                let file =
                    std::fs::OpenOptions::new().write(true).open(path)?;
                file.set_len(keep)?;
                Ok(true)
            }
        }
    }

    /// If the `nth` (0-based) train-state save is scheduled for
    /// truncation, consume that fault and return the byte count to keep.
    pub fn truncation_for_save(&self, nth: u64) -> Option<u64> {
        let mut fired = self.lock_fired();
        for (i, fault) in self.faults.iter().enumerate() {
            if fired[i] {
                continue;
            }
            if let Fault::Truncate { nth_save, keep } = fault {
                if *nth_save == nth {
                    fired[i] = true;
                    return Some(*keep);
                }
            }
        }
        None
    }

    /// Mark-and-collect the actions due at `(lane, step)`; stalls are
    /// ordered before the kill so a combined stall+kill clause both
    /// delays and fails the lane.
    fn poll(&self, lane: usize, step: u64) -> Vec<Action> {
        let mut fired = self.lock_fired();
        let mut stalls = Vec::new();
        let mut kill = None;
        for (i, fault) in self.faults.iter().enumerate() {
            if fired[i] {
                continue;
            }
            match fault {
                Fault::Kill { lane: l, step: s } if *l == lane && *s == step => {
                    fired[i] = true;
                    kill = Some(Action::Kill(InjectedFault { lane, step }));
                }
                Fault::Stall {
                    lane: l,
                    step: s,
                    millis,
                } if *l == lane && *s == step => {
                    fired[i] = true;
                    stalls.push(Action::Stall(*millis));
                }
                _ => {}
            }
        }
        stalls.extend(kill);
        stalls
    }

    fn lock_fired(&self) -> std::sync::MutexGuard<'_, Vec<bool>> {
        // Poison-tolerant: a kill unwinding a lane must not wedge the
        // plan for the surviving lanes or the recovery replay.
        self.fired.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::empty()
    }
}

impl fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "FaultPlan({:?}, fired {}/{})",
            self.spec(),
            self.fired_count(),
            self.faults.len()
        )
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec())
    }
}

fn parse_at(s: &str) -> Result<(u64, u64)> {
    let (a, b) = s
        .split_once('@')
        .with_context(|| format!("expected a@b, got '{s}'"))?;
    Ok((
        a.parse().with_context(|| format!("bad number '{a}'"))?,
        b.parse().with_context(|| format!("bad number '{b}'"))?,
    ))
}

/// Classify a caught panic payload: `(injected, message)`. Injected
/// faults carry an [`InjectedFault`]; everything else — `assert!`
/// strings, `&str` literals, exotic payloads — is a real bug.
pub fn describe_panic(payload: &(dyn std::any::Any + Send)) -> (bool, String) {
    if let Some(fault) = payload.downcast_ref::<InjectedFault>() {
        (true, fault.to_string())
    } else if let Some(s) = payload.downcast_ref::<String>() {
        (false, s.clone())
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (false, (*s).to_string())
    } else {
        (false, "<non-string panic>".to_string())
    }
}

/// Drop guard that writes a fault plan's spec to
/// `target/fault-plans/<name>.txt` if the current thread is panicking
/// when it drops — so a failing fault-injection test leaves a replayable
/// artifact for CI to upload.
pub struct FaultPlanArtifact {
    name: String,
    spec: String,
}

impl FaultPlanArtifact {
    pub fn new(name: &str, plan: &FaultPlan) -> FaultPlanArtifact {
        FaultPlanArtifact {
            name: name.to_string(),
            spec: plan.spec(),
        }
    }
}

impl Drop for FaultPlanArtifact {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        let dir = Path::new("target/fault-plans");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let body = format!(
            "{}\n# failing case: {}\n# replay: gum train --replicas R \
             --fault-plan '{}'  (or rerun the named test)\n",
            self.spec, self.name, self.spec
        );
        let _ = std::fs::write(dir.join(format!("{}.txt", self.name)), body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrips() {
        let spec = "kill:2@15,stall:0@3:50,trunc:1@64";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.spec(), spec);
        assert_eq!(plan.faults().len(), 3);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ,  ").unwrap().is_empty());
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in ["boom:1@2", "kill:1", "kill:a@2", "stall:1@2", "trunc:x@1"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} must fail");
        }
    }

    #[test]
    fn kill_fires_exactly_once_with_typed_payload() {
        let plan = FaultPlan::parse("kill:1@4").unwrap();
        // Wrong lane/step: nothing fires.
        plan.fire(0, 4);
        plan.fire(1, 3);
        assert_eq!(plan.fired_count(), 0);
        let caught = std::panic::catch_unwind(|| plan.fire(1, 4))
            .expect_err("kill must panic");
        let fault = caught
            .downcast_ref::<InjectedFault>()
            .expect("payload must be InjectedFault");
        assert_eq!(*fault, InjectedFault { lane: 1, step: 4 });
        // One-shot: the replay of the same step is clean.
        plan.fire(1, 4);
        assert_eq!(plan.fired_count(), 1);
    }

    #[test]
    fn check_returns_typed_error_once() {
        let plan = FaultPlan::parse("kill:0@2").unwrap();
        assert!(plan.check(0, 1).is_ok());
        let err = plan.check(0, 2).expect_err("planned kill");
        assert_eq!(err, InjectedFault { lane: 0, step: 2 });
        assert!(plan.check(0, 2).is_ok(), "one-shot");
    }

    #[test]
    fn truncation_consumes_by_save_index() {
        let plan = FaultPlan::parse("trunc:2@64").unwrap();
        assert_eq!(plan.truncation_for_save(0), None);
        assert_eq!(plan.truncation_for_save(2), Some(64));
        assert_eq!(plan.truncation_for_save(2), None, "one-shot");
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(7, 4, 20, 3);
        let b = FaultPlan::seeded(7, 4, 20, 3);
        assert_eq!(a.spec(), b.spec());
        assert_eq!(a.faults().len(), 3);
        assert_ne!(a.spec(), FaultPlan::seeded(8, 4, 20, 3).spec());
    }

    #[test]
    fn describe_panic_separates_injected_from_real() {
        let (injected, msg) =
            describe_panic(&InjectedFault { lane: 3, step: 9 });
        assert!(injected);
        assert!(msg.contains("lane 3"));
        let (injected, msg) = describe_panic(&"plain bug".to_string());
        assert!(!injected);
        assert_eq!(msg, "plain bug");
    }
}
