//! Model registry + host-side parameter store.
//!
//! Mirrors `python/compile/configs.py`: the same canonical block order is
//! the ABI between the Rust trainer and the AOT-lowered HLO programs
//! (checked at load time against `artifacts/manifest.json`).

mod params;
pub mod registry;

pub use params::{init_param_store, BlockKind, ParamBlock, ParamStore};
pub use registry::{paper_shape_table, ModelConfig, PaperModel};
