//! Model size registry (mirror of `python/compile/configs.py`) plus the
//! paper's 7–9B shape tables used for analytic memory accounting
//! (Table 3).

/// Transformer size configuration. Field meanings match the Python side.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Canonical ordered (name, shape) block list — MUST match
    /// `ModelConfig.param_blocks()` in `python/compile/configs.py`.
    pub fn param_blocks(&self) -> Vec<(String, Vec<usize>)> {
        let d = self.dim;
        let f = self.ffn;
        let mut blocks: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![self.vocab, d])];
        for i in 0..self.n_layers {
            let p = format!("layers.{i}.");
            blocks.push((format!("{p}attn_norm"), vec![d]));
            blocks.push((format!("{p}wq"), vec![d, d]));
            blocks.push((format!("{p}wk"), vec![d, d]));
            blocks.push((format!("{p}wv"), vec![d, d]));
            blocks.push((format!("{p}wo"), vec![d, d]));
            blocks.push((format!("{p}mlp_norm"), vec![d]));
            blocks.push((format!("{p}w_gate"), vec![d, f]));
            blocks.push((format!("{p}w_up"), vec![d, f]));
            blocks.push((format!("{p}w_down"), vec![f, d]));
        }
        blocks.push(("final_norm".into(), vec![d]));
        blocks.push(("lm_head".into(), vec![d, self.vocab]));
        blocks
    }

    pub fn n_params(&self) -> usize {
        self.param_blocks()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

/// Built-in model sizes. Runnable sizes use byte vocab; the 60m–350m
/// LLaMA sizes match the GaLore/paper table (vocab 32000).
pub fn registry() -> Vec<ModelConfig> {
    let c = |name: &str, vocab, dim, n_layers, n_heads, ffn, seq_len, batch| {
        ModelConfig {
            name: name.into(),
            vocab,
            dim,
            n_layers,
            n_heads,
            ffn,
            seq_len,
            batch,
        }
    };
    vec![
        c("micro", 256, 64, 2, 4, 192, 64, 8),
        c("tiny", 256, 128, 4, 4, 384, 128, 8),
        c("small", 512, 256, 6, 8, 768, 128, 8),
        c("llama-60m", 32000, 512, 8, 8, 1376, 1024, 8),
        c("llama-130m", 32000, 768, 12, 12, 2048, 1024, 8),
        c("llama-350m", 32000, 1024, 24, 16, 2736, 1024, 8),
    ]
}

/// Look up a config by name.
pub fn get(name: &str) -> Option<ModelConfig> {
    registry().into_iter().find(|c| c.name == name)
}

/// Shape table for the paper's fine-tuning models (Table 3's memory
/// columns): per-layer matrix shapes + layer count + embedding shapes.
/// These models are never *run* here; the accountant walks these shapes
/// analytically.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: &'static str,
    /// LM head tied to the embedding (Gemma-2).
    pub tied_embeddings: bool,
    pub n_layers: usize,
    pub dim: usize,
    pub ffn: usize,
    pub n_kv_heads: usize,
    pub n_heads: usize,
    pub vocab: usize,
}

/// LLaMA-3-8B, Qwen-2.5-7B, Gemma-2-9B (paper Table 5 + public configs).
pub fn paper_shape_table() -> Vec<PaperModel> {
    vec![
        PaperModel {
            name: "LLaMA-3-8B",
            tied_embeddings: false,
            n_layers: 32,
            dim: 4096,
            ffn: 14336,
            n_kv_heads: 8,
            n_heads: 32,
            vocab: 128256,
        },
        PaperModel {
            name: "Qwen-2.5-7B",
            tied_embeddings: false,
            n_layers: 28,
            dim: 3584,
            ffn: 18944,
            n_kv_heads: 4,
            n_heads: 28,
            vocab: 152064,
        },
        PaperModel {
            name: "Gemma-2-9B",
            tied_embeddings: true,
            n_layers: 42,
            dim: 3584,
            ffn: 14336,
            n_kv_heads: 8,
            n_heads: 16,
            vocab: 256000,
        },
    ]
}

impl PaperModel {
    pub fn head_dim(&self) -> usize {
        // Public configs: LLaMA-3 128, Qwen2.5 128, Gemma-2 256.
        match self.name {
            "Gemma-2-9B" => 256,
            _ => 128,
        }
    }

    /// 2-D projectable weight blocks (the ones GaLore/GUM touch).
    pub fn matrix_blocks(&self) -> Vec<(String, usize, usize)> {
        let d = self.dim;
        let hd = self.head_dim();
        let q = self.n_heads * hd;
        let kv = self.n_kv_heads * hd;
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            let p = format!("layers.{i}.");
            out.push((format!("{p}wq"), d, q));
            out.push((format!("{p}wk"), d, kv));
            out.push((format!("{p}wv"), d, kv));
            out.push((format!("{p}wo"), q, d));
            out.push((format!("{p}w_gate"), d, self.ffn));
            out.push((format!("{p}w_up"), d, self.ffn));
            out.push((format!("{p}w_down"), self.ffn, d));
        }
        out
    }

    pub fn n_params(&self) -> usize {
        let matrices: usize = self
            .matrix_blocks()
            .iter()
            .map(|(_, m, n)| m * n)
            .sum();
        // embeddings (+ untied head) + norms
        let embeds = if self.tied_embeddings { 1 } else { 2 };
        matrices
            + embeds * self.vocab * self.dim
            + (2 * self.n_layers + 1) * self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_expected_sizes() {
        let names: Vec<String> =
            registry().into_iter().map(|c| c.name).collect();
        assert!(names.contains(&"micro".to_string()));
        assert!(names.contains(&"llama-350m".to_string()));
    }

    #[test]
    fn micro_param_count_matches_python() {
        // Mirrors python/tests/test_model.py::test_n_params_micro.
        let c = get("micro").unwrap();
        let per_layer = 2 * 64 + 4 * 64 * 64 + 3 * 64 * 192;
        assert_eq!(c.n_params(), 2 * 256 * 64 + 64 + 2 * per_layer);
    }

    #[test]
    fn block_order_stable() {
        let c = get("micro").unwrap();
        let blocks = c.param_blocks();
        assert_eq!(blocks[0].0, "embed");
        assert_eq!(blocks[1].0, "layers.0.attn_norm");
        assert_eq!(blocks.last().unwrap().0, "lm_head");
        assert_eq!(blocks.len(), 3 + 9 * c.n_layers);
    }

    #[test]
    fn paper_models_are_billion_scale() {
        for m in paper_shape_table() {
            let b = m.n_params() as f64 / 1e9;
            assert!(b > 6.0 && b < 11.0, "{}: {b}B", m.name);
        }
    }
}
