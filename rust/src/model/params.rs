//! Host-side parameter store: named blocks of `Matrix` in canonical
//! order, with init matching the Python side's scheme (norms = 1,
//! matrices ~ N(0, fan_in⁻¹)).

use crate::linalg::Matrix;
use crate::rng::{derive_seed, Pcg};

use super::registry::ModelConfig;

/// Block classification for the optimizer: 2-D blocks large enough for
/// low-rank projection vs. everything else (norms, small blocks) which
/// always take dense updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// 2-D matrix eligible for GaLore/GUM projection + Muon.
    Projectable,
    /// 1-D (norm) or tiny block: dense base-optimizer update.
    Dense,
}

/// One named parameter block. 1-D blocks are stored as 1×d matrices.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamBlock {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: BlockKind,
    pub value: Matrix,
}

impl ParamBlock {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The full parameter set in canonical block order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamStore {
    pub blocks: Vec<ParamBlock>,
}

impl ParamStore {
    pub fn n_params(&self) -> usize {
        self.blocks.iter().map(|b| b.numel()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&ParamBlock> {
        self.blocks.iter().find(|b| b.name == name)
    }

    /// Indices of projectable blocks (the N_L "layers" of Algorithm 2).
    pub fn projectable_indices(&self) -> Vec<usize> {
        self.blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.kind == BlockKind::Projectable)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Embedding/LM-head blocks are conventionally excluded from projection
/// (GaLore applies to attention/MLP matrices); they take dense updates.
fn classify(name: &str, shape: &[usize]) -> BlockKind {
    let is_2d = shape.len() == 2 && shape[0] > 1 && shape[1] > 1;
    if !is_2d || name == "embed" || name == "lm_head" {
        BlockKind::Dense
    } else {
        BlockKind::Projectable
    }
}

/// Initialize parameters for a model config (deterministic per seed).
pub fn init_param_store(cfg: &ModelConfig, seed: u64) -> ParamStore {
    let blocks = cfg
        .param_blocks()
        .into_iter()
        .map(|(name, shape)| {
            let kind = classify(&name, &shape);
            let value = match shape.as_slice() {
                [d] => Matrix::from_vec(1, *d, vec![1.0; *d]),
                [m, n] => {
                    let mut rng =
                        Pcg::new(derive_seed(seed, &format!("init/{name}")));
                    let std = (*m as f32).powf(-0.5);
                    Matrix::randn(*m, *n, std, &mut rng)
                }
                other => panic!("unsupported block rank {other:?}"),
            };
            ParamBlock {
                name,
                shape,
                kind,
                value,
            }
        })
        .collect();
    ParamStore { blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::registry;

    fn micro() -> ModelConfig {
        registry::get("micro").unwrap()
    }

    #[test]
    fn init_matches_config_shapes() {
        let store = init_param_store(&micro(), 0);
        assert_eq!(store.blocks.len(), 3 + 9 * 2);
        assert_eq!(store.n_params(), micro().n_params());
        for b in &store.blocks {
            let expect_rows = if b.shape.len() == 1 { 1 } else { b.shape[0] };
            let expect_cols = *b.shape.last().unwrap();
            assert_eq!(b.value.shape(), (expect_rows, expect_cols), "{}", b.name);
        }
    }

    #[test]
    fn classification() {
        let store = init_param_store(&micro(), 0);
        assert_eq!(store.get("embed").unwrap().kind, BlockKind::Dense);
        assert_eq!(store.get("lm_head").unwrap().kind, BlockKind::Dense);
        assert_eq!(store.get("final_norm").unwrap().kind, BlockKind::Dense);
        assert_eq!(
            store.get("layers.0.wq").unwrap().kind,
            BlockKind::Projectable
        );
        assert_eq!(
            store.get("layers.1.w_down").unwrap().kind,
            BlockKind::Projectable
        );
        // 7 projectable matrices per layer × 2 layers
        assert_eq!(store.projectable_indices().len(), 14);
    }

    #[test]
    fn norms_init_to_one_matrices_scaled() {
        let store = init_param_store(&micro(), 0);
        let norm = store.get("layers.0.attn_norm").unwrap();
        assert!(norm.value.data.iter().all(|&v| v == 1.0));
        let wq = store.get("layers.0.wq").unwrap();
        let std = stat_std(&wq.value.data);
        assert!((std - 0.125).abs() < 0.02, "std {std}"); // 64^-0.5
    }

    #[test]
    fn deterministic_per_seed() {
        let a = init_param_store(&micro(), 1);
        let b = init_param_store(&micro(), 1);
        let c = init_param_store(&micro(), 2);
        assert_eq!(a.get("layers.0.wq").unwrap().value,
                   b.get("layers.0.wq").unwrap().value);
        assert_ne!(a.get("layers.0.wq").unwrap().value,
                   c.get("layers.0.wq").unwrap().value);
    }

    fn stat_std(xs: &[f32]) -> f32 {
        let n = xs.len() as f32;
        let mean: f32 = xs.iter().sum::<f32>() / n;
        (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n).sqrt()
    }
}
