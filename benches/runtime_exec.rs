//! PJRT runtime benches: L2 grad-step latency per model size, fwd/eval
//! latency, and the L1 HLO Newton–Schulz kernel vs the native Rust
//! implementation. Requires `make artifacts`.

use std::path::Path;

use gum::bench::Bench;
use gum::linalg::{newton_schulz, Matrix};
use gum::model::{init_param_store, registry};
use gum::rng::Pcg;
use gum::runtime::{Executor, HloKernels, ModelRunner};

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime_exec: artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let mut exec = Executor::new(dir)?;

    for model in ["micro", "tiny"] {
        let Some(cfg) = registry::get(model) else { continue };
        if exec
            .manifest
            .find(&format!("model_grad_{model}"))
            .is_none()
        {
            continue;
        }
        let runner = ModelRunner::new(&exec, &cfg)?;
        let params = init_param_store(&cfg, 0);
        let n = cfg.batch * cfg.seq_len;
        let tokens: Vec<i32> = (0..n).map(|i| (i % 200 + 4) as i32).collect();

        let b = Bench::new(&format!(
            "pjrt {model} (B{}xS{})",
            cfg.batch, cfg.seq_len
        ))
        .samples(10);
        b.run("grad_step", n as f64, "tok", || {
            let out = runner
                .grad_step(&mut exec, &params, &tokens, &tokens)
                .unwrap();
            gum::bench::bb(out.loss);
        });
        b.run("eval_fwd", n as f64, "tok", || {
            let out =
                runner.eval(&mut exec, &params, &tokens, &tokens).unwrap();
            gum::bench::bb(out.0);
        });
    }

    // L1 HLO kernel vs native Newton–Schulz.
    let b = Bench::new("newton_schulz: HLO(L1 Pallas) vs native").samples(10);
    let ns_shapes: Vec<(usize, usize)> = exec
        .manifest
        .entries
        .iter()
        .filter(|e| e.kind == "newton_schulz")
        .map(|e| (e.inputs[0].shape[0], e.inputs[0].shape[1]))
        .collect();
    let mut rng = Pcg::new(0);
    for (m, n) in ns_shapes {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        b.run_val(&format!("hlo_{m}x{n}"), 1.0, "op", || {
            HloKernels::newton_schulz(&mut exec, &g).unwrap()
        });
        b.run_val(&format!("native_{m}x{n}"), 1.0, "op", || {
            newton_schulz(&g, 5)
        });
    }
    // Machine-readable dump on request (--bench-json / GUM_BENCH_JSON).
    gum::bench::write_json_report("runtime_exec", None, Vec::new())?;
    Ok(())
}
