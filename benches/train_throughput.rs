//! End-to-end training throughput (tokens/s).
//!
//! Two groups:
//! 1. **Replica scaling** on the deterministic synthetic gradient engine
//!    — no AOT artifacts needed. Holds per-lane work constant (weak
//!    scaling), so aggregate tokens/s should grow ~linearly with lanes
//!    on a multi-core host: the acceptance bar is ≥ 2× at 4 replicas
//!    vs 1. The per-micro-batch FLOP ballast is single-threaded so the
//!    number measures lane fan-out, not nested GEMM parallelism.
//! 2. **Per-optimizer PJRT throughput** — the system-level number behind
//!    every Table-2/4 run. Requires `make artifacts`.

use std::path::PathBuf;

use gum::bench::Bench;
use gum::coordinator::{
    LrSchedule, ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource, TrainConfig, Trainer,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::model::{init_param_store, registry};
use gum::optim;

fn replica_session(
    replicas: usize,
) -> (ParallelSession, Vec<SyntheticGradSource>) {
    let model = registry::get("micro").unwrap();
    let params = init_param_store(&model, 0);
    let opt = optim::build("gum", &params, 8, 1.0, 7).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 1_000_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(model.vocab),
        model.batch,
        model.seq_len,
        &pcfg,
    );
    let mut source = SyntheticGradSource::new(&params, 3);
    source.work = 256; // ~tens of ms of single-threaded FLOPs per micro
    let sources = vec![source; replicas];
    let session = ParallelSession::new(
        params,
        opt,
        batcher,
        10,
        LrSchedule::constant(5e-3),
        11,
    );
    (session, sources)
}

fn main() -> anyhow::Result<()> {
    gum::util::logging::set_level(1); // quiet the trainer

    // --- Group 1: data-parallel replica scaling (no artifacts) ---
    let model = registry::get("micro").unwrap();
    let steps = 12usize;
    let b = Bench::new("replica scaling (synthetic grads, 12 global steps)")
        .warmup(1)
        .samples(3);
    let mut tputs: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let tokens =
            (steps * replicas * model.batch * model.seq_len) as f64;
        let stats =
            b.run(&format!("{replicas} replicas"), tokens, "tok", || {
                let (mut session, mut sources) = replica_session(replicas);
                for _ in 0..steps {
                    session.global_step(&mut sources).unwrap();
                }
                gum::bench::bb(session.step);
            });
        if let Some(s) = stats {
            tputs.push((replicas, tokens / s.mean_s));
        }
    }
    if let (Some(&(_, t1)), Some(&(_, t4))) = (
        tputs.iter().find(|(r, _)| *r == 1),
        tputs.iter().find(|(r, _)| *r == 4),
    ) {
        println!(
            "  aggregate scaling: 4 replicas vs 1 = {:.2}x (target >= 2x)",
            t4 / t1
        );
    }

    // --- Group 2: per-optimizer PJRT throughput (needs artifacts) ---
    if !PathBuf::from("artifacts/manifest.json").exists() {
        eprintln!(
            "train_throughput: artifacts missing — skipping PJRT cases \
             (run `make artifacts`)"
        );
        return Ok(());
    }

    let b = Bench::new("train 30 steps (micro)").warmup(1).samples(3);
    for opt in ["adamw", "muon", "galore-muon", "fira", "gum"] {
        let steps = 30usize;
        b.run(opt, (steps * 8 * 64) as f64, "tok", || {
            let cfg = TrainConfig {
                model: "micro".into(),
                optimizer: opt.into(),
                lr: 5e-3,
                steps,
                period_k: 10,
                rank: 16,
                gamma: 2.0,
                log_every: 0,
                ..TrainConfig::default()
            };
            let r = Trainer::new(cfg).run().unwrap();
            gum::bench::bb(r.final_train_loss);
        });
    }

    // Data-parallel splits of the same global batch through PJRT: both
    // consume 4 micro-batches per global step via the shared combine
    // path, so their traces agree (see train_loop.rs) and their cost
    // difference isolates the lane bookkeeping overhead.
    for (replicas, accum) in [(1usize, 4usize), (4, 1)] {
        let steps = 15usize;
        b.run(
            &format!("gum {replicas}r x {accum}a"),
            (steps * 4 * 8 * 64) as f64,
            "tok",
            || {
                let cfg = TrainConfig {
                    model: "micro".into(),
                    optimizer: "gum".into(),
                    lr: 5e-3,
                    steps,
                    period_k: 10,
                    rank: 16,
                    gamma: 2.0,
                    log_every: 0,
                    replicas,
                    accum_steps: accum,
                    shard_mode: ShardMode::Interleaved,
                    ..TrainConfig::default()
                };
                let r = Trainer::new(cfg).run().unwrap();
                gum::bench::bb(r.final_train_loss);
            },
        );
    }
    Ok(())
}
