//! End-to-end training throughput (tokens/s).
//!
//! Four groups:
//! 0. **Projector refresh** — exact Jacobi vs randomized vs warm-started
//!    subspace iteration across block shapes (the per-period hot path
//!    behind every GaLore/GUM run). Writes the `BENCH_projector.json`
//!    baseline; acceptance bar: **≥ 3× for randomized/warm vs exact at
//!    1024×4096, r = 128**. Filter `projector_refresh/smoke` for the CI
//!    smoke shape.
//! 0b. **Refresh overlap** — total period-boundary stall with the
//!    refresh on the critical path (`--refresh-pipeline sync`) vs
//!    overlapped on the worker pool (async, the default), through a real
//!    `ParallelSession` at 1024×2048 r128. Acceptance bar: **async
//!    stall ≤ ½ sync stall**.
//! 1. **Replica scaling** on the deterministic synthetic gradient engine
//!    — no AOT artifacts needed. Holds per-lane work constant (weak
//!    scaling), so aggregate tokens/s should grow ~linearly with lanes
//!    on a multi-core host: the acceptance bar is ≥ 2× at 4 replicas
//!    vs 1. The per-micro-batch FLOP ballast is single-threaded so the
//!    number measures lane fan-out, not nested GEMM parallelism.
//! 2. **Per-optimizer PJRT throughput** — the system-level number behind
//!    every Table-2/4 run. Requires `make artifacts`.

use std::path::PathBuf;

use gum::bench::Bench;
use gum::coordinator::{
    combine_lanes_compressed, LaneResult, LrSchedule, ParallelConfig,
    ParallelSession, ReduceMode, ReducePlan, ShardMode, ShardedBatcher,
    SyntheticGradSource, TrainConfig, Trainer,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::{rsvd, top_singular_vectors, Matrix, RsvdOpts};
use gum::model::{
    init_param_store, registry, BlockKind, ParamBlock, ParamStore,
};
use gum::optim::{self, Gum, RefreshPipelineMode};
use gum::rng::Pcg;
use gum::util::json::Json;

fn replica_session(
    replicas: usize,
) -> (ParallelSession, Vec<SyntheticGradSource>) {
    let model = registry::get("micro").unwrap();
    let params = init_param_store(&model, 0);
    let opt = optim::build("gum", &params, 8, 1.0, 7).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 1_000_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(model.vocab),
        model.batch,
        model.seq_len,
        &pcfg,
    );
    let mut source = SyntheticGradSource::new(&params, 3);
    source.work = 256; // ~tens of ms of single-threaded FLOPs per micro
    let sources = vec![source; replicas];
    let session = ParallelSession::new(
        params,
        opt,
        batcher,
        10,
        LrSchedule::constant(5e-3),
        11,
    );
    (session, sources)
}

fn main() -> anyhow::Result<()> {
    gum::util::logging::set_level(1); // quiet the trainer

    // JSON-report inputs assembled by group 0, written at every exit
    // of main so the document also carries the later groups' rows.
    let mut report_extra: Vec<(&str, Json)> = Vec::new();
    let mut report_default: Option<&str> = None;

    // --- Group 0: projector refresh (exact vs randomized vs warm) ---
    // One sample per case: the exact-Jacobi reference at 1024×4096 runs
    // a ~1024³·sweeps f64 eigendecomposition, and the speedups measured
    // here are order-of-magnitude, not percent-level.
    {
        let b = Bench::new("projector_refresh").warmup(0).samples(1);
        // Same filter the Bench harness applies per case, read up front
        // so filtered runs skip the (expensive) per-shape setup too.
        let filter = gum::bench::filter();
        let cold_opts = RsvdOpts::default();
        let warm_opts = RsvdOpts {
            oversample: cold_opts.oversample,
            power_iters: 1,
        };
        let mut rng = Pcg::new(0);
        let mut rows: Vec<Json> = Vec::new();
        let shapes = [
            (64usize, 256usize, 16usize, "smoke_64x256"),
            (256, 256, 128, "256x256"),
            (512, 1024, 128, "512x1024"),
            (1024, 4096, 128, "1024x4096"),
        ];
        for (m, n, r, tag) in shapes {
            if let Some(f) = &filter {
                let any_case = ["exact", "randomized", "warm"]
                    .iter()
                    .any(|c| {
                        format!("projector_refresh/{tag}/{c}")
                            .contains(f.as_str())
                    });
                if !any_case {
                    continue;
                }
            }
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            // Steady-state warm basis: the previous period's projector,
            // then a small gradient drift before the timed refresh.
            let prev = rsvd(&a, r, &cold_opts, None, &mut rng).u;
            let mut a2 = a.clone();
            a2.add_scaled_in_place(
                0.02,
                &Matrix::randn(m, n, 1.0, &mut rng),
            );

            let exact = b
                .run_val(&format!("{tag}/exact"), 1.0, "refresh", || {
                    top_singular_vectors(&a2, r)
                });
            let rand = b
                .run_val(&format!("{tag}/randomized"), 1.0, "refresh", || {
                    rsvd(&a2, r, &cold_opts, None, &mut rng).u
                });
            let warm = b
                .run_val(&format!("{tag}/warm"), 1.0, "refresh", || {
                    rsvd(&a2, r, &warm_opts, Some(&prev), &mut rng).u
                });

            if let (Some(e), Some(rd), Some(w)) = (exact, rand, warm) {
                let sp_r = e.mean_s / rd.mean_s.max(1e-12);
                let sp_w = e.mean_s / w.mean_s.max(1e-12);
                println!(
                    "  {tag} r={r}: randomized {sp_r:.1}x, warm-start \
                     {sp_w:.1}x vs exact (target >= 3x at 1024x4096)"
                );
                rows.push(Json::obj(vec![
                    ("shape", Json::str(tag)),
                    ("rows", Json::num(m as f64)),
                    ("cols", Json::num(n as f64)),
                    ("rank", Json::num(r as f64)),
                    ("exact_s", Json::num(e.mean_s)),
                    ("randomized_s", Json::num(rd.mean_s)),
                    ("warm_s", Json::num(w.mean_s)),
                    ("speedup_randomized", Json::num(sp_r)),
                    ("speedup_warm", Json::num(sp_w)),
                ]));
            }
        }
        // A complete sweep refreshes the default baseline path; a
        // partial (filtered) run writes only to an explicitly requested
        // `--bench-json`/`GUM_BENCH_JSON` path — e.g. the CI smoke
        // artifact — and never clobbers `BENCH_projector.json`. The
        // document uses the shared emitter schema (flat harness `cases`
        // rows) with the per-shape speedup records under `sweep`; the
        // write itself happens at the end of main so the later groups'
        // rows are included.
        let complete = rows.len() == shapes.len();
        if complete {
            report_default = Some("BENCH_projector.json");
        } else if gum::bench::json_path().is_none() {
            println!(
                "  partial projector_refresh run: \
                 BENCH_projector.json left untouched"
            );
        }
        report_extra = vec![
            ("seed", Json::num(0.0)),
            ("complete_sweep", Json::Bool(complete)),
            ("oversample", Json::num(cold_opts.oversample as f64)),
            ("power_iters", Json::num(cold_opts.power_iters as f64)),
            (
                "warm_power_iters",
                Json::num(warm_opts.power_iters as f64),
            ),
            ("sweep", Json::arr(rows)),
        ];
    }

    // --- Group 0b: refresh overlap (sync vs async pipeline stall) ---
    {
        let session_for = |mode: RefreshPipelineMode| {
            let mut rng = Pcg::new(3);
            let params = ParamStore {
                blocks: vec![ParamBlock {
                    name: "w".into(),
                    shape: vec![1024, 2048],
                    kind: BlockKind::Projectable,
                    value: Matrix::randn(1024, 2048, 0.1, &mut rng),
                }],
            };
            let opt = optim::build("gum", &params, 128, 1.0, 7).unwrap();
            let pcfg = ParallelConfig {
                replicas: 1,
                accum_steps: 1,
                shard_mode: ShardMode::DocPartition,
                doc_stride: 1_000_000,
            };
            let batcher = ShardedBatcher::new(
                &CorpusSpec::default(),
                &ByteTokenizer::new(256),
                4,
                32,
                &pcfg,
            );
            let mut session = ParallelSession::new(
                params,
                opt,
                batcher,
                5,
                LrSchedule::constant(1e-3),
                11,
            );
            session.set_refresh_mode(mode);
            let mut source = SyntheticGradSource::new(&session.params, 5);
            source.work = 24; // fwd/bwd stand-in for the overlap window
            (session, vec![source])
        };
        let b = Bench::new("refresh_overlap (1024x2048 r128, K=5)")
            .warmup(0)
            .samples(2);
        let steps = 11usize; // two overlapped handoffs per run
        let mut stalls: Vec<(RefreshPipelineMode, f64, usize)> = Vec::new();
        for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
            let mut last: Option<(f64, usize)> = None;
            b.run(
                &format!("{}_run", mode.label()),
                steps as f64,
                "step",
                || {
                    let (mut session, mut sources) = session_for(mode);
                    for _ in 0..steps {
                        session.global_step(&mut sources).unwrap();
                    }
                    last = Some((
                        session.refresh.stall_seconds(),
                        session.refresh.handoffs(),
                    ));
                    gum::bench::bb(session.step);
                },
            );
            if let Some((stall, handoffs)) = last {
                stalls.push((mode, stall, handoffs));
            }
        }
        if let (Some(sync), Some(asy)) = (
            stalls
                .iter()
                .find(|(m, ..)| *m == RefreshPipelineMode::Sync),
            stalls
                .iter()
                .find(|(m, ..)| *m == RefreshPipelineMode::Async),
        ) {
            let ratio = sync.1 / asy.1.max(1e-9);
            println!(
                "  period-boundary stall: sync {:.2}ms vs async {:.2}ms \
                 over {} handoffs = {ratio:.1}x less stall (target >= 2x)",
                sync.1 * 1e3,
                asy.1 * 1e3,
                sync.2
            );
            report_extra.push((
                "refresh_overlap",
                Json::obj(vec![
                    ("sync_stall_s", Json::num(sync.1)),
                    ("async_stall_s", Json::num(asy.1)),
                    ("handoffs", Json::num(sync.2 as f64)),
                    ("stall_reduction", Json::num(ratio)),
                ]),
            ));
        }
    }

    // --- Group 0c: reduce payload (dense vs low-rank all-reduce) ---
    // Byte accounting and combine time for the `--reduce lowrank` path,
    // against a *real* GUM session: the payload plan comes from the
    // period's committed projectors and the live full-rank Bernoulli
    // mask, so the sampled full-rank blocks are accounted at dense
    // size. Acceptance bar: **≥ 4× payload reduction at 8 blocks of
    // 1024×4096, r = 128, γ = 1** — which holds whenever the period's
    // draw sampled ≤ γ full-rank blocks (the expected count), so the
    // harness advances whole periods until a draw at or under the
    // expectation is in force. Filter `reduce_bytes/smoke` for the CI
    // smoke shape.
    {
        let filter = gum::bench::filter();
        let b = Bench::new("reduce_bytes").warmup(0).samples(2);
        let shapes = [
            (2usize, 64usize, 256usize, 16usize, "smoke_2x64x256_r16"),
            (8, 1024, 4096, 128, "8x1024x4096_r128"),
        ];
        let replicas = 2usize;
        let mut rows: Vec<Json> = Vec::new();
        for (blocks, m, n, r, tag) in shapes {
            if let Some(f) = &filter {
                let any_case = ["dense", "lowrank"].iter().any(|c| {
                    format!("reduce_bytes/{tag}/{c}").contains(f.as_str())
                });
                if !any_case {
                    continue;
                }
            }
            let mut rng = Pcg::new(9);
            let params = ParamStore {
                blocks: (0..blocks)
                    .map(|i| ParamBlock {
                        name: format!("w{i}"),
                        shape: vec![m, n],
                        kind: BlockKind::Projectable,
                        value: Matrix::randn(m, n, 0.05, &mut rng),
                    })
                    .collect(),
            };
            let opt = optim::build("gum", &params, r, 1.0, 7).unwrap();
            let pcfg = ParallelConfig {
                replicas,
                accum_steps: 1,
                shard_mode: ShardMode::DocPartition,
                doc_stride: 1_000_000,
            };
            let batcher = ShardedBatcher::new(
                &CorpusSpec::default(),
                &ByteTokenizer::new(256),
                4,
                32,
                &pcfg,
            );
            // K = 3: the smallest period with a step that is neither a
            // boundary nor the next boundary's refresh trigger — i.e.
            // a step whose plan actually compresses.
            let mut session = ParallelSession::new(
                params,
                opt,
                batcher,
                3,
                LrSchedule::constant(1e-3),
                11,
            );
            session.set_reduce_mode(ReduceMode::LowRank);
            let mut sources =
                vec![SyntheticGradSource::new(&session.params, 5); replicas];
            session.global_step(&mut sources)?; // boundary: mask + bases
            let sampled = |s: &ParallelSession| {
                s.opt
                    .as_any()
                    .and_then(|a| a.downcast_ref::<Gum>())
                    .expect("bench runs GUM")
                    .full_rank_mask()
                    .iter()
                    .filter(|&&b| b)
                    .count()
            };
            let mut tries = 0;
            while sampled(&session) > 1 && tries < 12 {
                for _ in 0..3 {
                    session.global_step(&mut sources)?;
                }
                tries += 1;
            }
            let full_rank = sampled(&session);
            assert_eq!(session.step % 3, 1, "must sit mid-period");
            let plan = session.reduce_plan();

            let lane_grads: Vec<Vec<Matrix>> = (0..replicas)
                .map(|_| {
                    (0..blocks)
                        .map(|_| Matrix::randn(m, n, 1.0, &mut rng))
                        .collect()
                })
                .collect();
            let mk_lanes = |grads: &[Vec<Matrix>]| -> Vec<LaneResult> {
                grads
                    .iter()
                    .enumerate()
                    .map(|(rep, g)| LaneResult {
                        replica: rep,
                        loss: 1.0,
                        grads: g.clone(),
                        micro_batches: 1,
                        grad_time_s: 0.0,
                        tokens: 128,
                    })
                    .collect()
            };
            // Both cases pay the same lane-clone cost inside the timed
            // closure, so their delta isolates the reduce itself.
            let dense_plan = ReducePlan::dense(blocks);
            let dense_stats = b.run_val(
                &format!("{tag}/dense"),
                0.0,
                "",
                || combine_lanes_compressed(mk_lanes(&lane_grads), &dense_plan),
            );
            let lowrank_stats = b.run_val(
                &format!("{tag}/lowrank"),
                0.0,
                "",
                || combine_lanes_compressed(mk_lanes(&lane_grads), &plan),
            );
            let (_, acct) =
                combine_lanes_compressed(mk_lanes(&lane_grads), &plan);
            println!(
                "  {tag}: {} of {blocks} blocks full-rank-sampled, \
                 per-lane {} -> {} bytes = {:.2}x payload reduction \
                 (target >= 4x at 8x1024x4096_r128)",
                full_rank,
                acct.dense_bytes,
                acct.payload_bytes,
                acct.compression()
            );
            rows.push(Json::obj(vec![
                ("shape", Json::str(tag)),
                ("blocks", Json::num(blocks as f64)),
                ("rows", Json::num(m as f64)),
                ("cols", Json::num(n as f64)),
                ("rank", Json::num(r as f64)),
                ("replicas", Json::num(replicas as f64)),
                ("full_rank_blocks", Json::num(full_rank as f64)),
                ("dense_bytes", Json::num(acct.dense_bytes as f64)),
                ("payload_bytes", Json::num(acct.payload_bytes as f64)),
                ("compression", Json::num(acct.compression())),
                (
                    "dense_combine_s",
                    dense_stats
                        .as_ref()
                        .map_or(Json::Null, |s| Json::num(s.mean_s)),
                ),
                (
                    "lowrank_combine_s",
                    lowrank_stats
                        .as_ref()
                        .map_or(Json::Null, |s| Json::num(s.mean_s)),
                ),
            ]));
        }
        report_extra.push(("reduce_bytes", Json::arr(rows)));
    }

    // --- Group 1: data-parallel replica scaling (no artifacts) ---
    let model = registry::get("micro").unwrap();
    let steps = 12usize;
    let b = Bench::new("replica scaling (synthetic grads, 12 global steps)")
        .warmup(1)
        .samples(3);
    let mut tputs: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let tokens =
            (steps * replicas * model.batch * model.seq_len) as f64;
        let stats =
            b.run(&format!("{replicas} replicas"), tokens, "tok", || {
                let (mut session, mut sources) = replica_session(replicas);
                for _ in 0..steps {
                    session.global_step(&mut sources).unwrap();
                }
                gum::bench::bb(session.step);
            });
        if let Some(s) = stats {
            tputs.push((replicas, tokens / s.mean_s));
        }
    }
    if let (Some(&(_, t1)), Some(&(_, t4))) = (
        tputs.iter().find(|(r, _)| *r == 1),
        tputs.iter().find(|(r, _)| *r == 4),
    ) {
        println!(
            "  aggregate scaling: 4 replicas vs 1 = {:.2}x (target >= 2x)",
            t4 / t1
        );
    }

    // --- Group 2: per-optimizer PJRT throughput (needs artifacts) ---
    if !PathBuf::from("artifacts/manifest.json").exists() {
        eprintln!(
            "train_throughput: artifacts missing — skipping PJRT cases \
             (run `make artifacts`)"
        );
        gum::bench::write_json_report(
            "train_throughput",
            report_default,
            report_extra,
        )?;
        return Ok(());
    }

    let b = Bench::new("train 30 steps (micro)").warmup(1).samples(3);
    for opt in ["adamw", "muon", "galore-muon", "fira", "gum"] {
        let steps = 30usize;
        b.run(opt, (steps * 8 * 64) as f64, "tok", || {
            let cfg = TrainConfig {
                model: "micro".into(),
                optimizer: opt.into(),
                lr: 5e-3,
                steps,
                period_k: 10,
                rank: 16,
                gamma: 2.0,
                log_every: 0,
                ..TrainConfig::default()
            };
            let r = Trainer::new(cfg).run().unwrap();
            gum::bench::bb(r.final_train_loss);
        });
    }

    // Data-parallel splits of the same global batch through PJRT: both
    // consume 4 micro-batches per global step via the shared combine
    // path, so their traces agree (see train_loop.rs) and their cost
    // difference isolates the lane bookkeeping overhead.
    for (replicas, accum) in [(1usize, 4usize), (4, 1)] {
        let steps = 15usize;
        b.run(
            &format!("gum {replicas}r x {accum}a"),
            (steps * 4 * 8 * 64) as f64,
            "tok",
            || {
                let cfg = TrainConfig {
                    model: "micro".into(),
                    optimizer: "gum".into(),
                    lr: 5e-3,
                    steps,
                    period_k: 10,
                    rank: 16,
                    gamma: 2.0,
                    log_every: 0,
                    replicas,
                    accum_steps: accum,
                    shard_mode: ShardMode::Interleaved,
                    ..TrainConfig::default()
                };
                let r = Trainer::new(cfg).run().unwrap();
                gum::bench::bb(r.final_train_loss);
            },
        );
    }

    gum::bench::write_json_report(
        "train_throughput",
        report_default,
        report_extra,
    )?;
    Ok(())
}
