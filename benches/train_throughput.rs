//! End-to-end training throughput (tokens/s) per optimizer — the
//! system-level number behind every Table-2/4 run. Requires artifacts.

use std::path::PathBuf;

use gum::bench::Bench;
use gum::coordinator::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    if !PathBuf::from("artifacts/manifest.json").exists() {
        eprintln!("train_throughput: artifacts missing — run `make artifacts`");
        return Ok(());
    }
    gum::util::logging::set_level(1); // quiet the trainer

    let b = Bench::new("train 30 steps (micro)").warmup(1).samples(3);
    for opt in ["adamw", "muon", "galore-muon", "fira", "gum"] {
        let steps = 30usize;
        b.run(opt, (steps * 8 * 64) as f64, "tok", || {
            let cfg = TrainConfig {
                model: "micro".into(),
                optimizer: opt.into(),
                lr: 5e-3,
                steps,
                period_k: 10,
                rank: 16,
                gamma: 2.0,
                log_every: 0,
                ..TrainConfig::default()
            };
            let r = Trainer::new(cfg).run().unwrap();
            gum::bench::bb(r.final_train_loss);
        });
    }
    Ok(())
}
