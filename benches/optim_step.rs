//! Optimizer-step cost, three layers deep:
//!
//! 1. **Per-optimizer step latency** on the micro model's block set —
//!    the L3 optimizer cost that Table-2/4 runs pay every iteration.
//! 2. **Fused vs scalar elementwise** at the acceptance shape
//!    (1024×4096 dense block, r = 128 projected): each fused
//!    `linalg::elementwise` kernel against the scalar multi-pass loops
//!    the optimizers used before the engine existed (kept verbatim in
//!    `mod scalar`, the same convention as `benches/linalg.rs`'s legacy
//!    GEMM). Acceptance bar: **≥ 1.3× on the composite
//!    `step_elementwise` sequence**.
//! 3. **Sync vs async projector refresh** through a real
//!    `ParallelSession`: total period-boundary stall with the refresh on
//!    the critical path vs overlapped on the worker pool (the
//!    `train_throughput` refresh-overlap group measures the same thing
//!    at full session scale; bar: stall drops ≥ 2×).
//! 4. **Rank-schedule refresh cost**: `begin_period` under the fixed
//!    vs the adaptive (spectrum-controller) schedule at a production
//!    shape — the probe-at-ceiling + observe + truncate overhead the
//!    controller adds per refresh.
//! 5. **Period-schedule controller cost**: a short scheduler + refresh
//!    pipeline loop under the fixed vs adaptive period schedule — the
//!    subspace-drift measurement + controller decision the adaptive
//!    path adds to each prepared refresh.
//!
//! A full (unfiltered) run refreshes the checked-in `BENCH_optim.json`
//! baseline; `make bench-gate` compares fresh numbers against it.

use gum::bench::Bench;
use gum::coordinator::{
    LrSchedule, ParallelConfig, ParallelSession, PeriodScheduler, ShardMode,
    ShardedBatcher, SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::{elementwise, Matrix};
use gum::model::{init_param_store, registry, BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, AdaptivePeriodCfg, AdaptiveRankCfg, PeriodSchedule, RankSchedule,
    RefreshPipeline, RefreshPipelineMode, RefreshStrategy, StateDtype,
    StepCtx,
};
use gum::rng::Pcg;
use gum::util::json::Json;

/// The pre-engine scalar loops, verbatim from the optimizers before the
/// fused elementwise kernels — the baseline the acceptance criterion
/// compares against.
mod scalar {
    /// Old `Matrix::axpby_in_place`.
    pub fn axpby(a: f32, x: &mut [f32], b: f32, y: &[f32]) {
        for (xv, &yv) in x.iter_mut().zip(y) {
            *xv = a * *xv + b * *yv;
        }
    }

    /// Old GaLore/Fira projected-Adam zip loop.
    #[allow(clippy::too_many_arguments)]
    pub fn adam_update(
        upd: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
    ) {
        for (((uv, &gv), mv), vv) in
            upd.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut())
        {
            *mv = b1 * *mv + (1.0 - b1) * gv;
            *vv = b2 * *vv + (1.0 - b2) * gv * gv;
            *uv = (*mv / bc1) / ((*vv / bc2).sqrt() + eps);
        }
    }

    /// Old `DenseAdamW::step` body.
    #[allow(clippy::too_many_arguments)]
    pub fn adam_apply(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        b1: f32,
        b2: f32,
        bc1: f32,
        bc2: f32,
        eps: f32,
        lr: f32,
        wd: f32,
    ) {
        for i in 0..w.len() {
            let gi = g[i];
            let mi = b1 * m[i] + (1.0 - b1) * gi;
            let vi = b2 * v[i] + (1.0 - b2) * gi * gi;
            m[i] = mi;
            v[i] = vi;
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            let mut x = w[i];
            if wd > 0.0 {
                x -= lr * wd * x;
            }
            w[i] = x - lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

fn single_block_store(m: usize, n: usize, seed: u64) -> ParamStore {
    let mut rng = Pcg::new(seed);
    ParamStore {
        blocks: vec![ParamBlock {
            name: "w".into(),
            shape: vec![m, n],
            kind: BlockKind::Projectable,
            value: Matrix::randn(m, n, 0.1, &mut rng),
        }],
    }
}

fn refresh_session(
    mode: RefreshPipelineMode,
    period_k: usize,
) -> (ParallelSession, Vec<SyntheticGradSource>) {
    let params = single_block_store(512, 1024, 3);
    let opt = optim::build("gum", &params, 128, 1.0, 7).unwrap();
    let pcfg = ParallelConfig {
        replicas: 1,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 1_000_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        4,
        32,
        &pcfg,
    );
    let mut session = ParallelSession::new(
        params,
        opt,
        batcher,
        period_k,
        LrSchedule::constant(1e-3),
        11,
    );
    session.set_refresh_mode(mode);
    let mut source = SyntheticGradSource::new(&session.params, 5);
    // Per-step gradient ballast so the async refresh has real work to
    // overlap with — in a real run this is the fwd/bwd pass.
    source.work = 48;
    (session, vec![source])
}

fn main() {
    // --- Group 1: per-optimizer step latency (micro model) ---
    let cfg = registry::get("micro").unwrap();
    let store = init_param_store(&cfg, 0);
    let mut rng = Pcg::new(0);
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
        .collect();
    let n_params = store.n_params() as f64;

    let b = Bench::new("optimizer step (micro: 21 blocks, 0.14M params)")
        .samples(10);
    for name in [
        "sgd", "sgdm", "adamw", "muon", "galore-muon", "galore-adam",
        "golore-muon", "fira", "lisa", "gum",
    ] {
        let mut opt = optim::build(name, &store, 16, 2.0, 0).unwrap();
        let mut params = store.clone();
        let mut prng = Pcg::new(1);
        opt.begin_period(&params, &grads, &mut prng);
        let mut step = 0usize;
        b.run(&format!("{name}/step"), n_params / 1e6, "Mparam", || {
            opt.step(&mut params, &grads, &StepCtx { lr: 1e-3, step });
            step += 1;
        });
    }

    let b = Bench::new("begin_period (projector refresh + sampling)")
        .samples(8);
    for name in ["galore-muon", "golore-muon", "fira", "gum"] {
        let mut opt = optim::build(name, &store, 16, 2.0, 0).unwrap();
        let mut prng = Pcg::new(1);
        b.run(&format!("{name}/period"), 1.0, "period", || {
            opt.begin_period(&store, &grads, &mut prng);
        });
    }

    // --- Group 2: fused vs scalar elementwise @ 1024×4096, r = 128 ---
    let mut speedups: Vec<Json> = Vec::new();
    {
        let (m, n, r) = (1024usize, 4096usize, 128usize);
        let full = m * n;
        let low = r * n;
        let mut prng = Pcg::new(2);
        let g_full = Matrix::randn(m, n, 1.0, &mut prng).data;
        let rec = Matrix::randn(m, n, 1.0, &mut prng).data;
        let g_low = Matrix::randn(r, n, 1.0, &mut prng).data;
        let melems = full as f64 / 1e6;
        let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 1e-3, 0.01);
        let (bc1, bc2) = (1.0 - b1.powi(5), 1.0 - b2.powi(5));

        let b = Bench::new("elementwise fused vs scalar (1024x4096 r128)")
            .samples(10);
        // Per-arm state: the fused and scalar closures live at the same
        // time inside `record`, so each arm owns its own buffers.
        struct Arm {
            w: Vec<f32>,
            mom: Vec<f32>,
            m: Vec<f32>,
            v: Vec<f32>,
            upd: Vec<f32>,
            tmp: Vec<f32>,
        }
        let arm = |n_full: usize, n_low: usize| Arm {
            w: vec![0.1f32; n_full],
            mom: vec![0.0f32; n_full],
            m: vec![0.0f32; n_full],
            v: vec![0.0f32; n_full],
            upd: vec![0.0f32; n_low],
            tmp: vec![0.0f32; n_full],
        };
        let mut record = |case: &str,
                          fused: &mut dyn FnMut(),
                          scal: &mut dyn FnMut(),
                          work: f64| {
            let f = b.run(&format!("{case}/fused"), work, "Melem", fused);
            let s = b.run(&format!("{case}/scalar"), work, "Melem", scal);
            if let (Some(f), Some(s)) = (f, s) {
                let sp = s.mean_s / f.mean_s.max(1e-12);
                println!("  {case}: fused {sp:.2}x vs scalar");
                speedups.push(Json::obj(vec![
                    ("case", Json::str(case)),
                    ("fused_s", Json::num(f.mean_s)),
                    ("scalar_s", Json::num(s.mean_s)),
                    ("speedup", Json::num(sp)),
                ]));
            }
        };

        // Each case scopes its arms so only one pair of buffer sets
        // (~170 MB at this shape) is ever live.

        // Momentum decay + accumulate over the full block.
        {
            let (mut fa, mut sa) = (arm(full, low), arm(full, low));
            record(
                "axpby",
                &mut || elementwise::axpby(0.95, &mut fa.mom, 1.0, &g_full),
                &mut || scalar::axpby(0.95, &mut sa.mom, 1.0, &g_full),
                melems,
            );
        }

        // GUM's compensated full-rank momentum: fused single pass vs the
        // old compose-then-accumulate (axpby into a temp, then axpby).
        {
            let (mut fa, mut sa) = (arm(full, low), arm(full, low));
            record(
                "decay_accumulate2",
                &mut || {
                    elementwise::decay_accumulate2(
                        &mut fa.mom, 0.95, 2.5, &g_full, -2.5, &rec,
                    )
                },
                &mut || {
                    sa.tmp.copy_from_slice(&rec);
                    scalar::axpby(-2.5, &mut sa.tmp, 2.5, &g_full);
                    scalar::axpby(0.95, &mut sa.mom, 1.0, &sa.tmp);
                },
                melems,
            );
        }

        // Projected Adam moments (r×n).
        {
            let (mut fa, mut sa) = (arm(low, low), arm(low, low));
            record(
                "adam_update_r128",
                &mut || {
                    elementwise::adam_update(
                        &mut fa.upd, &g_low, &mut fa.m, &mut fa.v, b1, b2,
                        bc1, bc2, eps,
                    )
                },
                &mut || {
                    scalar::adam_update(
                        &mut sa.upd, &g_low, &mut sa.m, &mut sa.v, b1, b2,
                        bc1, bc2, eps,
                    )
                },
                low as f64 / 1e6,
            );
        }

        // Dense AdamW over the full block.
        {
            let (mut fa, mut sa) = (arm(full, low), arm(full, low));
            record(
                "adam_apply",
                &mut || {
                    elementwise::adam_apply(
                        &mut fa.w, &g_full, &mut fa.m, &mut fa.v, b1, b2,
                        bc1, bc2, eps, lr, wd,
                    )
                },
                &mut || {
                    scalar::adam_apply(
                        &mut sa.w, &g_full, &mut sa.m, &mut sa.v, b1, b2,
                        bc1, bc2, eps, lr, wd,
                    )
                },
                melems,
            );
        }

        // The composite acceptance case: every elementwise pass of one
        // GUM full-rank step + one dense AdamW step at this shape —
        // fused engine vs the pre-engine scalar sequence.
        {
            let (mut fa, mut sa) = (arm(full, low), arm(full, low));
            record(
                "step_elementwise",
                &mut || {
                    elementwise::decay_accumulate2(
                        &mut fa.mom, 0.95, 2.5, &g_full, -2.5, &rec,
                    );
                    elementwise::add_scaled(&mut fa.w, -1e-3, &fa.mom);
                    elementwise::adam_apply(
                        &mut fa.w, &g_full, &mut fa.m, &mut fa.v, b1, b2,
                        bc1, bc2, eps, lr, wd,
                    );
                },
                &mut || {
                    sa.tmp.copy_from_slice(&rec);
                    scalar::axpby(-2.5, &mut sa.tmp, 2.5, &g_full);
                    scalar::axpby(0.95, &mut sa.mom, 1.0, &sa.tmp);
                    scalar::axpby(1.0, &mut sa.w, -1e-3, &sa.mom);
                    scalar::adam_apply(
                        &mut sa.w, &g_full, &mut sa.m, &mut sa.v, b1, b2,
                        bc1, bc2, eps, lr, wd,
                    );
                },
                3.0 * melems,
            );
        }
        drop(record);
        if let Some(row) = speedups.last() {
            if row.get("case").and_then(Json::as_str)
                == Some("step_elementwise")
            {
                println!(
                    "  step_elementwise target: >= 1.3x fused vs scalar \
                     (got {:.2}x)",
                    row.get("speedup").and_then(|s| s.as_f64()).unwrap_or(0.0)
                );
            }
        }
    }

    // --- Group 2b: optimizer-state dtype (f32 vs bf16 moments) ---
    // A wide block (n ≫ m) so the 16-bit moment buffers dominate the
    // footprint over the always-f32 projector: at 256×4096 r32 the
    // moments are 32× the projector, so halving them must show a
    // ≥ 1.9× total-state reduction — asserted here (it's a
    // deterministic byte count, not a timing). The step-time ratio
    // (t_f32 / t_bf16; bar ≥ 0.8×, i.e. the fused bf16 step may cost
    // at most 25% over f32) goes into the JSON row for the gate.
    let mut dtype_rows: Vec<Json> = Vec::new();
    {
        let params = single_block_store(256, 4096, 5);
        let mut prng = Pcg::new(8);
        let grads: Vec<Matrix> = params
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut prng))
            .collect();
        let b = Bench::new("state_dtype (256x4096 r32)").samples(8);
        for opt_name in ["galore-adam", "fira"] {
            let mut stats: Vec<(StateDtype, f64, usize)> = Vec::new();
            for dtype in [StateDtype::F32, StateDtype::Bf16] {
                let mut opt = optim::build_with_state(
                    opt_name,
                    &params,
                    32,
                    1.0,
                    7,
                    RefreshStrategy::default(),
                    &RankSchedule::Fixed,
                    dtype,
                )
                .unwrap();
                let mut store = params.clone();
                let mut rng = Pcg::new(1);
                opt.begin_period(&store, &grads, &mut rng);
                let mut step = 0usize;
                let res = b.run(
                    &format!("{opt_name}/{}", dtype.label()),
                    256.0 * 4096.0 / 1e6,
                    "Melem",
                    || {
                        opt.step(
                            &mut store,
                            &grads,
                            &StepCtx { lr: 1e-3, step },
                        );
                        step += 1;
                    },
                );
                if let Some(s) = res {
                    stats.push((dtype, s.mean_s, opt.state_bytes()));
                }
            }
            if let [(_, f32_s, f32_bytes), (_, bf16_s, bf16_bytes)] =
                stats.as_slice()
            {
                let reduction = *f32_bytes as f64 / (*bf16_bytes).max(1) as f64;
                let step_ratio = f32_s / bf16_s.max(1e-12);
                println!(
                    "  {opt_name}: bf16 state {bf16_bytes} B vs f32 \
                     {f32_bytes} B = {reduction:.2}x smaller (target >= \
                     1.9x), step ratio {step_ratio:.2}x (target >= 0.8x)"
                );
                assert!(
                    reduction >= 1.9,
                    "{opt_name}: bf16 opt_state_bytes reduction {reduction:.2}x \
                     below the 1.9x bar"
                );
                dtype_rows.push(Json::obj(vec![
                    ("case", Json::str(format!("state_dtype_{opt_name}"))),
                    ("f32_s", Json::num(*f32_s)),
                    ("bf16_s", Json::num(*bf16_s)),
                    ("f32_bytes", Json::num(*f32_bytes as f64)),
                    ("bf16_bytes", Json::num(*bf16_bytes as f64)),
                    ("bytes_reduction", Json::num(reduction)),
                    ("speedup", Json::num(step_ratio)),
                ]));
            }
        }
    }

    // --- Group 3: sync vs async projector refresh (session stall) ---
    let mut refresh_rows: Vec<Json> = Vec::new();
    {
        let period_k = 6usize;
        let steps = 3 * period_k + 1; // three overlapped handoffs
        let b = Bench::new("refresh pipeline (512x1024 r128, K=6)")
            .warmup(0)
            .samples(2);
        let mut stalls: Vec<(RefreshPipelineMode, f64, usize)> = Vec::new();
        for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
            let mut last: Option<(f64, usize)> = None;
            b.run(&format!("{}_run", mode.label()), steps as f64, "step", || {
                let (mut session, mut sources) =
                    refresh_session(mode, period_k);
                for _ in 0..steps {
                    session.global_step(&mut sources).unwrap();
                }
                last = Some((
                    session.refresh.stall_seconds(),
                    session.refresh.handoffs(),
                ));
                gum::bench::bb(session.step);
            });
            if let Some((stall, handoffs)) = last {
                stalls.push((mode, stall, handoffs));
            }
        }
        if let (Some(sync), Some(asy)) = (
            stalls
                .iter()
                .find(|(m, ..)| *m == RefreshPipelineMode::Sync),
            stalls
                .iter()
                .find(|(m, ..)| *m == RefreshPipelineMode::Async),
        ) {
            let ratio = sync.1 / asy.1.max(1e-9);
            println!(
                "  period-boundary stall: sync {:.2}ms vs async {:.2}ms \
                 over {} handoffs = {ratio:.1}x less stall (target >= 2x)",
                sync.1 * 1e3,
                asy.1 * 1e3,
                sync.2
            );
            refresh_rows.push(Json::obj(vec![
                ("sync_stall_s", Json::num(sync.1)),
                ("async_stall_s", Json::num(asy.1)),
                ("handoffs", Json::num(sync.2 as f64)),
                ("stall_reduction", Json::num(ratio)),
            ]));
        }
    }

    // --- Group 4: rank-schedule controller cost at the refresh ---
    // The adaptive schedule's per-refresh overhead on top of the fixed
    // path: probe at the rank ceiling + spectrum observation +
    // truncation, on a production-shaped block. The JSON row records
    // the committed total rank so the CI smoke run also checks the
    // controller actually engages.
    let mut rank_rows: Vec<Json> = Vec::new();
    {
        let params = single_block_store(512, 1024, 3);
        let mut prng = Pcg::new(4);
        let grads: Vec<Matrix> = params
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut prng))
            .collect();
        let b = Bench::new("rank_schedule (512x1024 base r128)").samples(8);
        for (label, schedule) in [
            ("fixed", RankSchedule::Fixed),
            ("adaptive", RankSchedule::Adaptive(AdaptiveRankCfg::default())),
        ] {
            let mut opt = optim::build_with_schedule(
                "gum",
                &params,
                128,
                1.0,
                7,
                RefreshStrategy::default(),
                &schedule,
            )
            .unwrap();
            let mut rng = Pcg::new(1);
            let res = b.run(&format!("{label}/period"), 1.0, "period", || {
                opt.begin_period(&params, &grads, &mut rng);
            });
            if let Some(stats) = res {
                let total_rank = opt
                    .rank_state()
                    .map(|s| s.total() as f64)
                    .unwrap_or(128.0);
                rank_rows.push(Json::obj(vec![
                    ("schedule", Json::str(label)),
                    ("period_s", Json::num(stats.mean_s)),
                    ("total_rank", Json::num(total_rank)),
                ]));
            }
        }
    }

    // --- Group 5: period-schedule controller cost at the boundary ---
    // The adaptive period schedule's overhead on top of the fixed path:
    // old-basis snapshot + principal-angle drift measurement + the
    // controller decision, all inside the prepared refresh. The JSON
    // row records the committed boundary count and final period so the
    // CI smoke run also checks the controller actually engages.
    let mut period_rows: Vec<Json> = Vec::new();
    {
        let params = single_block_store(512, 1024, 3);
        let mut prng = Pcg::new(6);
        let grads: Vec<Matrix> = params
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut prng))
            .collect();
        let base_k = 4usize;
        let steps = 3 * base_k + 1;
        let b = Bench::new("period_schedule (512x1024 r128, K=4)")
            .warmup(0)
            .samples(4);
        for (label, schedule) in [
            ("fixed", PeriodSchedule::Fixed),
            (
                "adaptive",
                PeriodSchedule::Adaptive(AdaptivePeriodCfg::default()),
            ),
        ] {
            let mut last: Option<(usize, usize)> = None;
            let res = b.run(&format!("{label}/run"), steps as f64, "step", || {
                let mut opt =
                    optim::build("gum", &params, 128, 0.0, 7).unwrap();
                let mut periods =
                    PeriodScheduler::with_schedule(base_k, &schedule);
                let mut pipeline =
                    RefreshPipeline::new(RefreshPipelineMode::Sync, 13);
                let mut rng = Pcg::new(1);
                for step in 0..steps {
                    if periods.is_period_start(step) {
                        let taken = pipeline.take(step);
                        let decision =
                            taken.as_ref().and_then(|p| p.period_state.clone());
                        match taken {
                            Some(prepared) => opt.begin_period_prepared(
                                &params, &grads, &mut rng, prepared,
                            ),
                            None => opt.begin_period(&params, &grads, &mut rng),
                        }
                        periods.commit_boundary(step, decision.as_ref());
                    }
                    pipeline.observe(step, &periods, &*opt, &grads);
                }
                last = Some((
                    periods.boundaries_committed(),
                    periods.current_period(),
                ));
                gum::bench::bb(periods.current_period());
            });
            if let (Some(stats), Some((refreshes, final_k))) = (res, last) {
                period_rows.push(Json::obj(vec![
                    ("schedule", Json::str(label)),
                    ("run_s", Json::num(stats.mean_s)),
                    ("refreshes", Json::num(refreshes as f64)),
                    ("final_period", Json::num(final_k as f64)),
                ]));
            }
        }
    }

    // Machine-readable dump: a full (unfiltered) run refreshes the
    // checked-in BENCH_optim.json baseline; filtered runs only write to
    // an explicit --bench-json/GUM_BENCH_JSON path.
    let default_path = if gum::bench::filter().is_none() {
        Some("BENCH_optim.json")
    } else {
        None
    };
    gum::bench::write_json_report(
        "optim_step",
        default_path,
        vec![
            ("elementwise_speedups", Json::arr(speedups)),
            ("state_dtype", Json::arr(dtype_rows)),
            ("refresh_overlap", Json::arr(refresh_rows)),
            ("rank_schedule", Json::arr(rank_rows)),
            ("period_schedule", Json::arr(period_rows)),
        ],
    )
    .expect("bench JSON write");
}
