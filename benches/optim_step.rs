//! Per-optimizer step latency on the micro model's block set — the L3
//! optimizer cost that Table-2/4 runs pay every iteration (paper-method
//! comparison at matched shapes).

use gum::bench::Bench;
use gum::linalg::Matrix;
use gum::model::{init_param_store, registry};
use gum::optim::{self, StepCtx};
use gum::rng::Pcg;

fn main() {
    let cfg = registry::get("micro").unwrap();
    let store = init_param_store(&cfg, 0);
    let mut rng = Pcg::new(0);
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
        .collect();
    let n_params = store.n_params() as f64;

    let b = Bench::new("optimizer step (micro: 21 blocks, 0.14M params)")
        .samples(10);
    for name in [
        "sgd", "sgdm", "adamw", "muon", "galore-muon", "galore-adam",
        "golore-muon", "fira", "lisa", "gum",
    ] {
        let mut opt = optim::build(name, &store, 16, 2.0, 0).unwrap();
        let mut params = store.clone();
        let mut prng = Pcg::new(1);
        opt.begin_period(&params, &grads, &mut prng);
        let mut step = 0usize;
        b.run(&format!("{name}/step"), n_params / 1e6, "Mparam", || {
            opt.step(&mut params, &grads, &StepCtx { lr: 1e-3, step });
            step += 1;
        });
    }

    let b = Bench::new("begin_period (projector refresh + sampling)")
        .samples(8);
    for name in ["galore-muon", "golore-muon", "fira", "gum"] {
        let mut opt = optim::build(name, &store, 16, 2.0, 0).unwrap();
        let mut prng = Pcg::new(1);
        b.run(&format!("{name}/period"), 1.0, "period", || {
            opt.begin_period(&store, &grads, &mut prng);
        });
    }

    // Machine-readable dump on request (--bench-json / GUM_BENCH_JSON).
    gum::bench::write_json_report("optim_step", None, Vec::new())
        .expect("bench JSON write");
}
