//! Linalg hot-path benches: GEMM, SVD (projector refresh), Newton–Schulz
//! (per-step Muon direction), QR. These are the L3 FLOP sinks profiled
//! in EXPERIMENTS.md §Perf.

use gum::bench::Bench;
use gum::linalg::{
    matmul, matmul_nt, matmul_tn, newton_schulz, qr_orthonormal, svd_thin,
    Matrix,
};
use gum::rng::Pcg;

fn main() {
    let mut rng = Pcg::new(0);

    let b = Bench::new("gemm").samples(10);
    for n in [64usize, 128, 256, 512] {
        let x = Matrix::randn(n, n, 1.0, &mut rng);
        let y = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        b.run_val(&format!("nn_{n}x{n}"), flops / 1e9, "GFLOP", || {
            matmul(&x, &y)
        });
    }
    // The optimizer's actual shapes (micro/tiny blocks).
    for (m, k, n, tag) in [
        (16usize, 64usize, 192usize, "project r16 d64xf192"),
        (64, 64, 192, "gram 64xf192"),
        (128, 128, 384, "tiny gram"),
    ] {
        let x = Matrix::randn(m, k, 1.0, &mut rng);
        let y = Matrix::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        b.run_val(tag, flops / 1e9, "GFLOP", || matmul(&x, &y));
    }
    {
        let x = Matrix::randn(256, 256, 1.0, &mut rng);
        let y = Matrix::randn(256, 256, 1.0, &mut rng);
        let flops = 2.0 * 256f64.powi(3);
        b.run_val("tn_256", flops / 1e9, "GFLOP", || matmul_tn(&x, &y));
        b.run_val("nt_256", flops / 1e9, "GFLOP", || matmul_nt(&x, &y));
    }

    let b = Bench::new("svd (GaLore projector refresh)").samples(8);
    for (m, n) in [(64usize, 192usize), (128, 384), (256, 768)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        b.run_val(&format!("{m}x{n}"), 1.0, "op", || svd_thin(&g));
    }

    let b = Bench::new("newton_schulz (Muon direction)").samples(10);
    for (m, n) in [(16usize, 192usize), (64, 192), (128, 384), (256, 768)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        b.run_val(&format!("{m}x{n}_5it"), 1.0, "op", || {
            newton_schulz(&g, 5)
        });
    }

    let b = Bench::new("qr (GoLore projector)").samples(8);
    for (m, r) in [(192usize, 16usize), (384, 32)] {
        let a = Matrix::randn(m, r, 1.0, &mut rng);
        b.run_val(&format!("{m}x{r}"), 1.0, "op", || qr_orthonormal(&a));
    }
}
