//! Linalg hot-path benches. The headline group is the **GEMM shape
//! sweep**: projection-shaped products (P·R, R·Pᵀ, PᵀG, accumulate)
//! over block shapes 64²…4096×1024 at ranks r ∈ {32, 128, 512}, timing
//! the packed cache-blocked kernel against the pre-packing (`legacy`)
//! kernel it replaced, and writing the machine-readable baseline
//! `BENCH_gemm.json` (override the path with `--bench-json` /
//! `GUM_BENCH_JSON`). Acceptance bar from the packing PR: **≥ 1.5× mean
//! throughput on the 1024×4096 r=128 NT and TN cases**.
//!
//! The **gemm_tuned** group times the shape-class autotuner against
//! the pinned fixed tiling on the tall-skinny projection family
//! (1024×4096 · r ∈ {32, 128, 512}, NT/TN) and records the geomean
//! speedup plus a warm-cache-skips-search check in the JSON extras.
//! Acceptance bar: **≥ 1.15× geomean tuned over fixed**.
//!
//! The SVD / Newton–Schulz / QR groups profile the other L3 FLOP sinks
//! (EXPERIMENTS.md §Perf); their rows ride along in the JSON report.
//!
//! CI runs `--bench-filter smoke` (the 64² cases) non-gating on every
//! push and uploads the JSON as a workflow artifact.

use gum::bench::{self, Bench};
use gum::linalg::{
    gemm, matmul, matmul_nt, matmul_tn, newton_schulz, qr_orthonormal,
    svd_thin, Matrix,
};
use gum::rng::Pcg;
use gum::util::json::Json;

/// The kernel this PR replaced: row-panel-parallel dot-product GEMM
/// with explicit `transpose()` materialization on the NN/TN paths and
/// an axpy row-update kernel for the accumulate form. Kept verbatim as
/// the speedup reference so `BENCH_gemm.json` records packed-vs-legacy
/// on every regeneration.
mod legacy {
    use gum::linalg::Matrix;
    use gum::thread::parallel_chunks;

    const PAR_MIN_ROWS: usize = 16;

    struct SendMut<T>(*mut T);
    unsafe impl<T> Sync for SendMut<T> {}
    unsafe impl<T> Send for SendMut<T> {}

    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let bt = b.transpose();
        matmul_nt(a, &bt)
    }

    pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
        let at = a.transpose();
        let bt = b.transpose();
        matmul_nt(&at, &bt)
    }

    pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols, "legacy matmul_nt dims");
        let (m, n, k) = (a.rows, b.rows, a.cols);
        let mut c = Matrix::zeros(m, n);
        let a_data = &a.data;
        let b_data = &b.data;
        let c_ptr = SendMut(c.data.as_mut_ptr());
        parallel_chunks(m, PAR_MIN_ROWS, |r0, r1| {
            let c_ptr = &c_ptr;
            for i in r0..r1 {
                let c_row = unsafe {
                    std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n)
                };
                let a_row = &a_data[i * k..(i + 1) * k];
                let mut j = 0;
                while j + 4 <= n {
                    let (d0, d1, d2, d3) = dot4(
                        a_row,
                        &b_data[j * k..(j + 1) * k],
                        &b_data[(j + 1) * k..(j + 2) * k],
                        &b_data[(j + 2) * k..(j + 3) * k],
                        &b_data[(j + 3) * k..(j + 4) * k],
                    );
                    c_row[j] = d0;
                    c_row[j + 1] = d1;
                    c_row[j + 2] = d2;
                    c_row[j + 3] = d3;
                    j += 4;
                }
                for j in j..n {
                    c_row[j] = dot(a_row, &b_data[j * k..(j + 1) * k]);
                }
            }
        });
        c
    }

    pub fn gemm(alpha: f32, a: &Matrix, b: &Matrix, beta: f32, c: &mut Matrix) {
        assert_eq!(a.cols, b.rows, "legacy gemm dims");
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let a_data = &a.data;
        let b_data = &b.data;
        let c_ptr = SendMut(c.data.as_mut_ptr());
        parallel_chunks(m, PAR_MIN_ROWS, |r0, r1| {
            let c_ptr = &c_ptr;
            for i in r0..r1 {
                let c_row = unsafe {
                    std::slice::from_raw_parts_mut(c_ptr.0.add(i * n), n)
                };
                if beta == 0.0 {
                    c_row.fill(0.0);
                } else if beta != 1.0 {
                    for v in c_row.iter_mut() {
                        *v *= beta;
                    }
                }
                let a_row = &a_data[i * k..(i + 1) * k];
                for (kk, &aik) in a_row.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    axpy(alpha * aik, &b_data[kk * n..(kk + 1) * n], c_row);
                }
            }
        });
    }

    #[inline]
    fn axpy(s: f32, b: &[f32], c: &mut [f32]) {
        let n = c.len();
        let lanes = n / 16 * 16;
        let (bh, bt) = b.split_at(lanes);
        let (ch, ct) = c.split_at_mut(lanes);
        for (cc, bb) in ch.chunks_exact_mut(16).zip(bh.chunks_exact(16)) {
            for l in 0..16 {
                cc[l] += s * bb[l];
            }
        }
        for (cc, bb) in ct.iter_mut().zip(bt) {
            *cc += s * bb;
        }
    }

    #[inline]
    fn dot4(
        a: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = a.len();
        let lanes = n / 16 * 16;
        let mut acc0 = [0.0f32; 16];
        let mut acc1 = [0.0f32; 16];
        let mut acc2 = [0.0f32; 16];
        let mut acc3 = [0.0f32; 16];
        let (ah, at) = a.split_at(lanes);
        let (b0h, b0t) = b0.split_at(lanes);
        let (b1h, b1t) = b1.split_at(lanes);
        let (b2h, b2t) = b2.split_at(lanes);
        let (b3h, b3t) = b3.split_at(lanes);
        for ((((aa, x0), x1), x2), x3) in ah
            .chunks_exact(16)
            .zip(b0h.chunks_exact(16))
            .zip(b1h.chunks_exact(16))
            .zip(b2h.chunks_exact(16))
            .zip(b3h.chunks_exact(16))
        {
            for l in 0..16 {
                acc0[l] += aa[l] * x0[l];
                acc1[l] += aa[l] * x1[l];
                acc2[l] += aa[l] * x2[l];
                acc3[l] += aa[l] * x3[l];
            }
        }
        let mut s0: f32 = acc0.iter().sum();
        let mut s1: f32 = acc1.iter().sum();
        let mut s2: f32 = acc2.iter().sum();
        let mut s3: f32 = acc3.iter().sum();
        for (i, &x) in at.iter().enumerate() {
            s0 += x * b0t[i];
            s1 += x * b1t[i];
            s2 += x * b2t[i];
            s3 += x * b3t[i];
        }
        (s0, s1, s2, s3)
    }

    #[inline]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let lanes = n / 16 * 16;
        let mut acc = [0.0f32; 16];
        let (ah, at) = a.split_at(lanes);
        let (bh, bt) = b.split_at(lanes);
        for (aa, bb) in ah.chunks_exact(16).zip(bh.chunks_exact(16)) {
            for l in 0..16 {
                acc[l] += aa[l] * bb[l];
            }
        }
        let mut s: f32 = acc.iter().sum();
        for (x, y) in at.iter().zip(bt) {
            s += x * y;
        }
        s
    }
}

fn main() -> std::io::Result<()> {
    let mut rng = Pcg::new(0);
    let filter = bench::filter();

    // --- GEMM shape sweep: packed vs legacy over projection shapes ---
    // (m, n) is the gradient-block shape, r the projection rank; the
    // four op variants are the per-step products of the projected
    // optimizers (DESIGN.md §3a). Sample counts scale down with case
    // cost so the 4096-shapes stay affordable.
    let shapes: &[(usize, usize)] = &[
        (64, 64),
        (256, 256),
        (512, 1024),
        (1024, 4096),
        (4096, 1024),
    ];
    let ranks = [32usize, 128, 512];
    const OPS: [&str; 4] = ["nn", "nt", "tn", "gemm_acc"];
    let b_small = Bench::new("gemm").warmup(3).samples(16);
    let b_mid = b_small.reconfigured(2, 8);
    let b_big = b_small.reconfigured(1, 5);
    let mut sweep_rows: Vec<Json> = Vec::new();
    for &(m, n) in shapes {
        for r in ranks {
            if r > m.min(n) {
                continue;
            }
            let smoke = if m * n <= 64 * 64 { "smoke_" } else { "" };
            // Skip the (expensive) per-shape setup when the filter
            // selects none of this shape's cases.
            if let Some(f) = &filter {
                let any = OPS.iter().any(|op| {
                    format!("gemm/{smoke}{op}_{m}x{n}_r{r}_legacy")
                        .contains(f.as_str())
                });
                if !any {
                    continue;
                }
            }
            let p_left = Matrix::randn(m, r, 1.0, &mut rng); // m×r
            let low = Matrix::randn(r, n, 1.0, &mut rng); // r×n
            let p_right = Matrix::randn(n, r, 1.0, &mut rng); // n×r
            let r_right = Matrix::randn(m, r, 1.0, &mut rng); // m×r
            let g = Matrix::randn(m, n, 1.0, &mut rng); // m×n
            let flops = 2.0 * (m * n * r) as f64;
            let b = if flops > 1e9 {
                &b_big
            } else if flops > 1e7 {
                &b_mid
            } else {
                &b_small
            };

            // One-shot correctness cross-check per shape: packed and
            // legacy must agree to accumulation-order tolerance.
            {
                let packed = matmul_nt(&r_right, &p_right);
                let old = legacy::matmul_nt(&r_right, &p_right);
                let err = packed.max_abs_diff(&old);
                assert!(
                    err < 1e-2 * (r as f32).sqrt(),
                    "packed vs legacy NT mismatch {err} at {m}x{n} r{r}"
                );
            }

            // nn: project-back left, P·R.
            let packed = b.run_val(
                &format!("{smoke}nn_{m}x{n}_r{r}"),
                flops / 1e9,
                "GFLOP",
                || matmul(&p_left, &low),
            );
            let old = b.run_val(
                &format!("{smoke}nn_{m}x{n}_r{r}_legacy"),
                flops / 1e9,
                "GFLOP",
                || legacy::matmul(&p_left, &low),
            );
            if let (Some(p), Some(o)) = (packed, old) {
                sweep_rows.push(sweep_row("nn", m, n, r, flops, &p, &o));
            }

            // nt: project-back right, R·Pᵀ.
            let packed = b.run_val(
                &format!("{smoke}nt_{m}x{n}_r{r}"),
                flops / 1e9,
                "GFLOP",
                || matmul_nt(&r_right, &p_right),
            );
            let old = b.run_val(
                &format!("{smoke}nt_{m}x{n}_r{r}_legacy"),
                flops / 1e9,
                "GFLOP",
                || legacy::matmul_nt(&r_right, &p_right),
            );
            if let (Some(p), Some(o)) = (packed, old) {
                sweep_rows.push(sweep_row("nt", m, n, r, flops, &p, &o));
            }

            // tn: projection PᵀG.
            let packed = b.run_val(
                &format!("{smoke}tn_{m}x{n}_r{r}"),
                flops / 1e9,
                "GFLOP",
                || matmul_tn(&p_left, &g),
            );
            let old = b.run_val(
                &format!("{smoke}tn_{m}x{n}_r{r}_legacy"),
                flops / 1e9,
                "GFLOP",
                || legacy::matmul_tn(&p_left, &g),
            );
            if let (Some(p), Some(o)) = (packed, old) {
                sweep_rows.push(sweep_row("tn", m, n, r, flops, &p, &o));
            }

            // gemm_acc: C += P·R (the fused accumulate form).
            let mut c_packed = Matrix::zeros(m, n);
            let packed = b.run_val(
                &format!("{smoke}gemm_acc_{m}x{n}_r{r}"),
                flops / 1e9,
                "GFLOP",
                || gemm(1.0, &p_left, &low, 1.0, &mut c_packed),
            );
            let mut c_legacy = Matrix::zeros(m, n);
            let old = b.run_val(
                &format!("{smoke}gemm_acc_{m}x{n}_r{r}_legacy"),
                flops / 1e9,
                "GFLOP",
                || legacy::gemm(1.0, &p_left, &low, 1.0, &mut c_legacy),
            );
            if let (Some(p), Some(o)) = (packed, old) {
                sweep_rows.push(sweep_row("gemm_acc", m, n, r, flops, &p, &o));
            }
        }
    }

    // --- Tuned-vs-fixed tall-skinny sweep (autotuner acceptance) ---
    // The projection family the autotuner specializes: 1024×4096
    // gradient blocks at r ∈ {32, 128, 512}, NT (R·Pᵀ, narrow-k) and
    // TN (PᵀG, narrow-m). `fixed` pins the default tiling through
    // `gemm_forced`; `tuned` routes through the driver with the tuner
    // on against a bench-local cache, so the one-time search lands in
    // the warmup phase and samples time the steady state. Acceptance
    // bar: ≥1.15× geomean (recorded as `tuned_geomean` in the JSON
    // extras, alongside a warm-cache-skips-search check).
    let mut tuned_rows: Vec<Json> = Vec::new();
    let mut tuned_geomean: Option<f64> = None;
    let mut warm_cache_ok: Option<bool> = None;
    {
        use gum::linalg::tune::{self, TuneMode};
        use gum::linalg::{gemm_forced, gemm_nt, gemm_tn};

        let (m, n) = (1024usize, 4096usize);
        let tuned_ranks = [32usize, 128, 512];
        let selected = filter.as_ref().map_or(true, |f| {
            tuned_ranks.iter().any(|r| {
                ["nt", "tn"].iter().any(|op| {
                    format!("gemm_tuned/tuned_{op}_{m}x{n}_r{r}")
                        .contains(f.as_str())
                })
            })
        });
        if selected {
            let cache = std::path::PathBuf::from("target/bench-tune-cache.json");
            let _ = std::fs::remove_file(&cache); // cold search per bench run
            let prev_mode = tune::set_mode(Some(TuneMode::On));
            let prev_path = tune::set_cache_path(Some(cache));
            tune::reset();

            let b = Bench::new("gemm_tuned").warmup(2).samples(6);
            let mut log_speedups: f64 = 0.0;
            let mut rows = 0usize;
            for r in tuned_ranks {
                let p_left = Matrix::randn(m, r, 1.0, &mut rng); // m×r
                let p_right = Matrix::randn(n, r, 1.0, &mut rng); // n×r
                let r_right = Matrix::randn(m, r, 1.0, &mut rng); // m×r
                let g = Matrix::randn(m, n, 1.0, &mut rng); // m×n
                let flops = 2.0 * (m * n * r) as f64;
                let cases: [(&str, bool); 2] = [("nt", true), ("tn", false)];
                for (op, is_nt) in cases {
                    let mut c = if is_nt {
                        Matrix::zeros(m, n)
                    } else {
                        Matrix::zeros(r, n)
                    };
                    let fixed = b.run_val(
                        &format!("fixed_{op}_{m}x{n}_r{r}"),
                        flops / 1e9,
                        "GFLOP",
                        || {
                            if is_nt {
                                gemm_forced(
                                    1.0, &r_right, &p_right, 0.0, &mut c,
                                    false, true, tune::fixed_config(),
                                );
                            } else {
                                gemm_forced(
                                    1.0, &p_left, &g, 0.0, &mut c, true,
                                    false, tune::fixed_config(),
                                );
                            }
                        },
                    );
                    let c_fixed = c.clone();
                    let tuned = b.run_val(
                        &format!("tuned_{op}_{m}x{n}_r{r}"),
                        flops / 1e9,
                        "GFLOP",
                        || {
                            if is_nt {
                                gemm_nt(1.0, &r_right, &p_right, 0.0, &mut c);
                            } else {
                                gemm_tn(1.0, &p_left, &g, 0.0, &mut c);
                            }
                        },
                    );
                    // Tuned tiles may split the k-reduction differently
                    // (kc), so compare to accumulation-order tolerance.
                    let err = c.max_abs_diff(&c_fixed);
                    assert!(
                        err < 1e-2 * (r as f32).sqrt(),
                        "tuned vs fixed mismatch {err} at {op} r{r}"
                    );
                    if let (Some(f), Some(t)) = (fixed, tuned) {
                        let speedup = f.mean_s / t.mean_s;
                        log_speedups += speedup.ln();
                        rows += 1;
                        tuned_rows.push(Json::obj(vec![
                            ("op", Json::str(op)),
                            ("m", Json::num(m as f64)),
                            ("n", Json::num(n as f64)),
                            ("r", Json::num(r as f64)),
                            ("flops", Json::num(flops)),
                            ("fixed_mean_s", Json::num(f.mean_s)),
                            ("fixed_gflops", Json::num(flops / 1e9 / f.mean_s)),
                            ("tuned_mean_s", Json::num(t.mean_s)),
                            ("tuned_gflops", Json::num(flops / 1e9 / t.mean_s)),
                            ("speedup", Json::num(speedup)),
                        ]));
                    }
                }
            }
            if rows > 0 {
                let geomean = (log_speedups / rows as f64).exp();
                tuned_geomean = Some(geomean);
                println!(
                    "gemm_tuned geomean speedup (tuned/fixed, {rows} cases): \
                     {geomean:.3}x (bar: 1.15x)"
                );
            }

            // Warm-cache check: drop the in-memory table, keep the file;
            // the reload must serve every class without a new search.
            tune::reset();
            let mut c = Matrix::zeros(m, n);
            let p_right = Matrix::randn(n, 128, 1.0, &mut rng);
            let r_right = Matrix::randn(m, 128, 1.0, &mut rng);
            gemm_nt(1.0, &r_right, &p_right, 0.0, &mut c);
            let warm = tune::searches_performed() == 0;
            warm_cache_ok = Some(warm);
            println!(
                "gemm_tuned warm cache skips search: {}",
                if warm { "yes" } else { "NO (searched again)" }
            );

            tune::set_cache_path(prev_path);
            tune::set_mode(prev_mode);
        }
    }

    // --- The other L3 FLOP sinks (ride along in the JSON report) ---
    let b = Bench::new("svd (GaLore projector refresh)").samples(8);
    for (m, n) in [(64usize, 192usize), (128, 384), (256, 768)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        b.run_val(&format!("{m}x{n}"), 1.0, "op", || svd_thin(&g));
    }

    let b = Bench::new("newton_schulz (Muon direction)").samples(10);
    for (m, n) in [(16usize, 192usize), (64, 192), (128, 384), (256, 768)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        b.run_val(&format!("{m}x{n}_5it"), 1.0, "op", || {
            newton_schulz(&g, 5)
        });
    }

    let b = Bench::new("qr (GoLore projector)").samples(8);
    for (m, r) in [(192usize, 16usize), (384, 32)] {
        let a = Matrix::randn(m, r, 1.0, &mut rng);
        b.run_val(&format!("{m}x{r}"), 1.0, "op", || qr_orthonormal(&a));
    }

    // Unfiltered full sweeps refresh the checked-in baseline; filtered
    // (partial) runs only write when a path was explicitly requested,
    // so a smoke run can't clobber the recorded trajectory. Unfiltered
    // runs execute every case, so the filter alone decides completeness.
    let complete = filter.is_none();
    let default_path = if complete { Some("BENCH_gemm.json") } else { None };
    let mut extras = vec![
        ("seed", Json::num(0.0)),
        ("complete_sweep", Json::Bool(complete)),
        ("sweep", Json::arr(sweep_rows)),
        ("tuned_sweep", Json::arr(tuned_rows)),
    ];
    if let Some(g) = tuned_geomean {
        extras.push(("tuned_geomean", Json::num(g)));
    }
    if let Some(w) = warm_cache_ok {
        extras.push(("tuned_warm_cache_skips_search", Json::Bool(w)));
    }
    bench::write_json_report("gemm_sweep", default_path, extras)?;
    Ok(())
}

fn sweep_row(
    op: &str,
    m: usize,
    n: usize,
    r: usize,
    flops: f64,
    packed: &gum::bench::Stats,
    legacy: &gum::bench::Stats,
) -> Json {
    Json::obj(vec![
        ("op", Json::str(op)),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("r", Json::num(r as f64)),
        ("flops", Json::num(flops)),
        ("packed_mean_s", Json::num(packed.mean_s)),
        ("packed_gflops", Json::num(flops / 1e9 / packed.mean_s)),
        ("legacy_mean_s", Json::num(legacy.mean_s)),
        ("legacy_gflops", Json::num(flops / 1e9 / legacy.mean_s)),
        ("speedup", Json::num(legacy.mean_s / packed.mean_s)),
    ])
}
